//! Tiny CLI argument parser (clap is not in the offline vendor set).
//!
//! Supports `--flag value`, `--flag=value`, bare `--switch`, positionals
//! and subcommands. The `fish` binary and every bench/example share it.

use std::collections::HashMap;
use std::fmt;

/// Parsed arguments: subcommand, flags, positionals.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// First non-flag token (if declared as a subcommand position).
    pub command: Option<String>,
    flags: HashMap<String, String>,
    switches: Vec<String>,
    /// Remaining positional tokens.
    pub positionals: Vec<String>,
}

/// CLI parse error.
#[derive(Debug)]
pub struct CliError(pub String);

impl fmt::Display for CliError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "cli error: {}", self.0)
    }
}

impl std::error::Error for CliError {}

impl Args {
    /// Parse raw args (without argv[0]). `with_command` treats the first
    /// positional as a subcommand.
    pub fn parse<I: IntoIterator<Item = String>>(argv: I, with_command: bool) -> Result<Args, CliError> {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if stripped.is_empty() {
                    // `--`: everything after is positional
                    out.positionals.extend(it);
                    break;
                }
                if let Some(eq) = stripped.find('=') {
                    out.flags
                        .insert(stripped[..eq].to_string(), stripped[eq + 1..].to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.flags.insert(stripped.to_string(), v);
                } else {
                    out.switches.push(stripped.to_string());
                }
            } else if with_command && out.command.is_none() {
                out.command = Some(tok);
            } else {
                out.positionals.push(tok);
            }
        }
        Ok(out)
    }

    /// Parse from the process environment.
    pub fn from_env(with_command: bool) -> Result<Args, CliError> {
        Args::parse(std::env::args().skip(1), with_command)
    }

    /// Raw flag value.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    /// Bare switch present?
    pub fn has(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name) || self.flags.contains_key(name)
    }

    /// Typed flag with default.
    pub fn get_or<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T, CliError> {
        match self.flags.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| CliError(format!("--{name}: cannot parse '{v}'"))),
        }
    }

    /// Typed required flag.
    pub fn require<T: std::str::FromStr>(&self, name: &str) -> Result<T, CliError> {
        let v = self
            .flags
            .get(name)
            .ok_or_else(|| CliError(format!("missing required --{name}")))?;
        v.parse()
            .map_err(|_| CliError(format!("--{name}: cannot parse '{v}'")))
    }

    /// Comma-separated list flag.
    pub fn get_list<T: std::str::FromStr>(&self, name: &str, default: &[T]) -> Result<Vec<T>, CliError>
    where
        T: Clone,
    {
        match self.flags.get(name) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|p| {
                    p.trim()
                        .parse()
                        .map_err(|_| CliError(format!("--{name}: cannot parse '{p}'")))
                })
                .collect(),
        }
    }

    /// Apply recognised flags onto a [`crate::config::Config`]; unknown
    /// flags are ignored (they may belong to the caller).
    pub fn apply_to_config(&self, cfg: &mut crate::config::Config) -> Result<(), CliError> {
        use crate::config::Value;
        let map_err = |e: crate::config::ConfigError| CliError(e.to_string());
        for (k, v) in &self.flags {
            let value = match k.as_str() {
                "scheme" | "workload" | "identifier" | "artifacts_dir" | "transport" => {
                    Value::Str(v.clone())
                }
                "tuples" | "sources" | "workers" | "key_capacity" | "epoch" | "d_min"
                | "interval" | "vnodes" | "seed" | "service_ns" | "interarrival_ns" | "batch"
                | "agg_flush_ms" | "agg_shards" | "agg_window_ms" | "agg_lateness_ms"
                | "processes" => {
                    Value::Int(v.parse().map_err(|_| CliError(format!("--{k}: bad int '{v}'")))?)
                }
                "zipf_z" | "alpha" | "theta_num" | "rebalance_threshold" => {
                    Value::Float(v.parse().map_err(|_| CliError(format!("--{k}: bad float '{v}'")))?)
                }
                "capacities" => {
                    let items: Result<Vec<Value>, CliError> = v
                        .split(',')
                        .map(|p| {
                            p.trim()
                                .parse::<f64>()
                                .map(Value::Float)
                                .map_err(|_| CliError(format!("--capacities: bad float '{p}'")))
                        })
                        .collect();
                    Value::Array(items?)
                }
                _ => continue,
            };
            cfg.set(k, &value).map_err(map_err)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str, cmd: bool) -> Args {
        Args::parse(s.split_whitespace().map(String::from), cmd).unwrap()
    }

    #[test]
    fn subcommand_flags_positionals() {
        // NB: a bare switch followed by a non-flag token would consume it
        // as a value (`--fast input.bin`), so switches go last or use `=`.
        let a = parse("run input.bin --workers 64 --fast", true);
        assert_eq!(a.command.as_deref(), Some("run"));
        assert_eq!(a.get("workers"), Some("64"));
        assert!(a.has("fast"));
        assert_eq!(a.positionals, vec!["input.bin"]);
    }

    #[test]
    fn equals_form_and_typed() {
        let a = parse("--alpha=0.3 --workers=8", false);
        assert_eq!(a.get_or("alpha", 0.0).unwrap(), 0.3);
        assert_eq!(a.get_or::<usize>("workers", 1).unwrap(), 8);
        assert_eq!(a.get_or("missing", 7u32).unwrap(), 7);
        assert!(a.require::<u32>("nope").is_err());
        assert!(a.get_or::<u32>("alpha", 0).is_err());
    }

    #[test]
    fn list_flag() {
        let a = parse("--capacities 1.0,2.0,2.0", false);
        let caps: Vec<f64> = a.get_list("capacities", &[1.0]).unwrap();
        assert_eq!(caps, vec![1.0, 2.0, 2.0]);
    }

    #[test]
    fn config_overrides() {
        let mut cfg = crate::config::Config::default();
        let a = parse("--scheme wc --workers 128 --alpha 0.5 --capacities 1,2", false);
        a.apply_to_config(&mut cfg).unwrap();
        assert_eq!(cfg.workers, 128);
        assert_eq!(cfg.alpha, 0.5);
        assert_eq!(cfg.capacities, vec![1.0, 2.0]);
        assert_eq!(cfg.scheme, crate::coordinator::SchemeKind::WChoices);
    }

    #[test]
    fn batch_and_threshold_flags_apply() {
        let mut cfg = crate::config::Config::default();
        let a = parse("--batch 1024 --rebalance_threshold 0.4 --agg_flush_ms 5", false);
        a.apply_to_config(&mut cfg).unwrap();
        assert_eq!(cfg.batch, 1024);
        assert!((cfg.rebalance_threshold - 0.4).abs() < 1e-12);
        assert_eq!(cfg.agg_flush_ms, 5);
    }

    #[test]
    fn agg_shards_flag_applies() {
        let mut cfg = crate::config::Config::default();
        let a = parse("--agg_shards 4", false);
        a.apply_to_config(&mut cfg).unwrap();
        assert_eq!(cfg.agg_shards, 4);
        let bad = parse("--agg_shards nope", false);
        assert!(bad.apply_to_config(&mut cfg).is_err());
    }

    #[test]
    fn agg_window_ms_flag_applies() {
        let mut cfg = crate::config::Config::default();
        let a = parse("--agg_window_ms 250", false);
        a.apply_to_config(&mut cfg).unwrap();
        assert_eq!(cfg.agg_window_ms, 250);
        let bad = parse("--agg_window_ms soon", false);
        assert!(bad.apply_to_config(&mut cfg).is_err());
    }

    #[test]
    fn transport_lateness_and_processes_flags_apply() {
        let mut cfg = crate::config::Config::default();
        let a = parse("--transport tcp --agg_lateness_ms 5 --processes 2", false);
        a.apply_to_config(&mut cfg).unwrap();
        assert_eq!(cfg.transport, "tcp");
        assert_eq!(cfg.agg_lateness_ms, 5);
        assert_eq!(cfg.processes, 2);
        let bad = parse("--processes several", false);
        assert!(bad.apply_to_config(&mut cfg).is_err());
    }

    #[test]
    fn double_dash_stops_parsing() {
        let a = parse("-- --not-a-flag", false);
        assert_eq!(a.positionals, vec!["--not-a-flag"]);
    }
}
