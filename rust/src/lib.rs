//! # FISH — Efficient Time-Evolving Stream Processing at Scale
//!
//! A from-scratch reproduction of the FISH grouping scheme (Yu Huang,
//! 2018) and the distributed-stream-processing substrate it runs on, as
//! the Layer-3 coordinator of a Rust + JAX + Pallas stack.
//!
//! ## Batch-first API
//!
//! The public surface is batch-first: jobs are built through the
//! [`engine::Pipeline`] builder and both engines drain tuples in
//! micro-batches through [`coordinator::Grouper::route_batch`], which
//! amortises per-tuple dispatch, hoists per-call work (view validation,
//! HWA interval re-estimation, counter sizing) out of the routing inner
//! loop, and is the shape the XLA `epoch_stats` backend needs (key
//! batches, not single keys):
//!
//! ```no_run
//! use fish::coordinator::SchemeKind;
//! use fish::engine::Pipeline;
//!
//! let result = Pipeline::builder()
//!     .workload("zf")
//!     .scheme(SchemeKind::Fish)
//!     .sources(4)
//!     .workers(32)
//!     .batch(1024)
//!     .tuples(1_000_000)
//!     .build_sim()
//!     .run();
//! println!("makespan {} / memory {:.2}x FG", result.makespan, result.memory_normalized);
//! ```
//!
//! The library is organised as:
//!
//! * [`workload`] — time-evolving stream generators (Zipf per the paper's
//!   §6.1 spec, MemeTracker-like and Amazon-Movie-like synthetic traces).
//! * [`sketch`] — frequency statistics: SpaceSaving (paper Alg. 1
//!   intra-epoch counter set) and a count-min sketch bit-compatible with
//!   the Pallas kernel in `python/compile/kernels/cms.py`.
//! * [`aggregate`] — the two-phase aggregation layer: per-worker
//!   partial aggregates flushed to a downstream merge fabric of
//!   key-range shards (`--agg_shards`, consistent-hash routed), turning
//!   the per-worker partials that key-splitting schemes produce into
//!   exact merged results, with global top-k answered exactly from the
//!   merged counts or approximately via the scatter-gather
//!   [`aggregate::TopKGather`] (per-shard SpaceSaving summaries with a
//!   rank-error bound).
//! * [`hashring`] — consistent hashing with virtual nodes (paper §5).
//! * [`coordinator`] — the grouping schemes behind the batch-first
//!   [`coordinator::Grouper`] trait: Shuffle, Field, Partial-Key,
//!   D-Choices, W-Choices and FISH (epoch identification + CHK + HWA).
//! * [`engine`] — the DSPE substrate: the [`engine::Pipeline`] builder,
//!   a deterministic discrete-event simulator (paper Figs. 2–17) and a
//!   real multithreaded runtime with bounded-queue backpressure and
//!   chunked per-worker sends (the Apache-Storm stand-in, Figs. 18–20).
//! * [`runtime`] — PJRT bridge: loads the AOT-compiled `epoch_stats` HLO
//!   artifacts and runs them from the coordinator hot path.
//! * [`transport`] — the distributed transport subsystem: lane traits
//!   over in-process loopback, UDS and TCP backends carrying a
//!   length-prefixed binary wire format with credit-based flow
//!   control, plus the `deploy --processes N` multi-process launcher.
//! * [`obs`] — lock-light tracing + telemetry: per-thread ring-buffered
//!   span/event recorders (virtual time in the sim, shared
//!   `transport::Clock` epoch time in rt/deploy), cross-process
//!   Chrome-trace timeline export (`--trace-out`, Perfetto-openable)
//!   and a per-epoch telemetry sampler (`--metrics-out` JSONL); see
//!   `docs/OBSERVABILITY.md`.
//! * [`analysis`] — the determinism & concurrency analysis suite:
//!   the `fish lint` source-level rule engine (unsorted map drains on
//!   flush paths, unwrap in transport I/O, relaxed credit atomics,
//!   raw clocks, non-exhaustive frame matches) and an explicit-state
//!   model checker for the credit flow-control protocol (see
//!   `docs/DETERMINISM.md`).
//! * [`metrics`], [`config`], [`cli`], [`report`], [`testing`], [`util`]
//!   — supporting substrates (hand-rolled: the build is offline).
//!
//! See `DESIGN.md` for the experiment index and `EXPERIMENTS.md` for the
//! paper-vs-measured record.

pub mod aggregate;
pub mod analysis;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod engine;
pub mod hashring;
pub mod metrics;
pub mod obs;
pub mod report;
pub mod runtime;
pub mod sketch;
pub mod state;
pub mod testing;
pub mod transport;
pub mod util;
pub mod workload;

/// A stream key. Keys are interned to dense ids by the workload layer;
/// the coordinator never sees raw strings on the hot path.
pub type Key = u64;

/// Index of a worker (downstream operator instance).
pub type WorkerId = usize;

/// Index of a source (upstream operator instance).
pub type SourceId = usize;
