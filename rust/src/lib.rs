//! # FISH — Efficient Time-Evolving Stream Processing at Scale
//!
//! A from-scratch reproduction of the FISH grouping scheme (Yu Huang,
//! 2018) and the distributed-stream-processing substrate it runs on, as
//! the Layer-3 coordinator of a Rust + JAX + Pallas stack.
//!
//! The library is organised as:
//!
//! * [`workload`] — time-evolving stream generators (Zipf per the paper's
//!   §6.1 spec, MemeTracker-like and Amazon-Movie-like synthetic traces).
//! * [`sketch`] — frequency statistics: SpaceSaving (paper Alg. 1
//!   intra-epoch counter set) and a count-min sketch bit-compatible with
//!   the Pallas kernel in `python/compile/kernels/cms.py`.
//! * [`hashring`] — consistent hashing with virtual nodes (paper §5).
//! * [`coordinator`] — the grouping schemes: Shuffle, Field, Partial-Key,
//!   D-Choices, W-Choices and FISH (epoch identification + CHK + HWA).
//! * [`engine`] — the DSPE substrate: a deterministic discrete-event
//!   simulator (paper Figs. 2–17) and a real multithreaded runtime with
//!   bounded-queue backpressure (the Apache-Storm stand-in, Figs. 18–20).
//! * [`runtime`] — PJRT bridge: loads the AOT-compiled `epoch_stats` HLO
//!   artifacts and runs them from the coordinator hot path.
//! * [`metrics`], [`config`], [`cli`], [`report`], [`testing`], [`util`]
//!   — supporting substrates (hand-rolled: the build is offline).
//!
//! See `DESIGN.md` for the experiment index and `EXPERIMENTS.md` for the
//! paper-vs-measured record.

pub mod cli;
pub mod config;
pub mod coordinator;
pub mod engine;
pub mod hashring;
pub mod metrics;
pub mod report;
pub mod runtime;
pub mod sketch;
pub mod state;
pub mod testing;
pub mod util;
pub mod workload;

/// A stream key. Keys are interned to dense ids by the workload layer;
/// the coordinator never sees raw strings on the hot path.
pub type Key = u64;

/// Index of a worker (downstream operator instance).
pub type WorkerId = usize;

/// Index of a source (upstream operator instance).
pub type SourceId = usize;
