//! Seeded regression for `fish lint`: a `FlushMsg` literal that hides
//! its exactly-once `seq` behind struct update — the frame ships with
//! a silently-defaulted sequence number and the shard sequencer
//! dedups or parks it (see `docs/RECOVERY.md`). This file is a lint
//! fixture, never compiled; the self-test in
//! `rust/tests/analysis_lint.rs` asserts the engine flags line 11.

use crate::transport::wire::FlushMsg;

pub fn resend(worker: usize, emit_ns: u64) -> FlushMsg {
    FlushMsg {
        worker,
        emit_ns,
        watermark: emit_ns,
        panes: Vec::new(),
        ..Default::default()
    }
}
