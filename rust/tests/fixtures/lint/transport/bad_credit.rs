//! Seeded regression for `fish lint`: a credit-protocol atomic
//! updated with `Ordering::Relaxed` — the grant could reorder past
//! the work it accounts for (see `docs/DETERMINISM.md`). This file
//! is a lint fixture, never compiled; the self-test in
//! `rust/tests/analysis_lint.rs` asserts the engine flags line 15.

use std::sync::atomic::{AtomicUsize, Ordering};

pub struct BadCredit {
    credits: AtomicUsize,
}

impl BadCredit {
    pub fn grant(&self, n: usize) {
        self.credits.fetch_add(n, Ordering::Relaxed);
    }
}
