//! Seeded regression for `fish lint`: a `ShardSnapshot` construction
//! that hides fields behind `..` — a newly added piece of shard state
//! would compile clean while silently skipping serialization, exactly
//! the failure mode the recovery tests exist to prevent. This file is
//! a lint fixture, never compiled; the self-test in
//! `rust/tests/analysis_lint.rs` asserts the engine flags line 14.

use crate::state::ShardSnapshot;

impl BadSnapshot {
    /// Carries only the cursors forward and defaults the rest — the
    /// merge state and buffered batches silently vanish on restore.
    pub fn checkpoint(&self) -> ShardSnapshot {
        ShardSnapshot { shard: self.shard, expected_seq: self.expected.clone(), ..Default::default() }
    }
}
