//! Seeded regression for `fish lint`: a per-batch `String` allocation
//! inside a hot-path absorb function — at millions of tuples per
//! second the allocator becomes the bottleneck (the ROADMAP
//! "allocation-free hot path" inventory). This file is a lint
//! fixture, never compiled; the self-test in
//! `rust/tests/analysis_lint.rs` asserts the engine flags line 17.

pub struct BadHotpath {
    tags: Vec<String>,
}

impl BadHotpath {
    /// Allocates a fresh `String` for every batch absorbed.
    pub fn absorb(&mut self, batch: &[u64]) {
        // building a label per call is pure allocator churn — compute
        // it once at construction or pass a &str through
        self.tags.push(batch.len().to_string());
    }

    /// Cold path: allocation here is fine, the rule must not fire.
    pub fn report(&self) -> String {
        format!("{} tags", self.tags.len())
    }
}
