//! Seeded regression for `fish lint`: an unsorted `HashMap::drain`
//! on a flush path — the exact bug class that made gather rankings
//! vary between identically-seeded runs (see `docs/DETERMINISM.md`).
//! This file is a lint fixture, never compiled; the self-test in
//! `rust/tests/analysis_lint.rs` asserts the engine flags line 16.

use std::collections::HashMap;

pub struct BadFlush {
    state: HashMap<u64, u64>,
}

impl BadFlush {
    /// Drains in hasher order — nondeterministic across runs.
    pub fn flush(&mut self) -> Vec<(u64, u64)> {
        self.state.drain().collect()
    }
}
