//! Seeded regression for `fish lint`: a raw `Instant::now()` inside
//! the tracing layer. The recorder is clock-agnostic by contract —
//! timestamps are passed in by the engines (virtual ticks in sim,
//! `transport::Clock` epoch ns in rt/deploy); a hidden clock read here
//! breaks sim trace determinism and cross-process timeline alignment.
//! This file is a lint fixture, never compiled; the self-test in
//! `rust/tests/analysis_lint.rs` asserts the engine flags line 13.

use std::time::Instant;

pub fn stamp_event(buf: &mut Vec<(Instant, &'static str)>, name: &'static str) {
    // self-stamping instead of taking `ts_ns: u64` from the caller
    buf.push((Instant::now(), name));
}
