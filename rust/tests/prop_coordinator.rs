//! Property-based tests over coordinator invariants (in-repo harness,
//! `fish::testing::prop_check` — proptest is unavailable offline).

use fish::config::Config;
use fish::coordinator::{make_kind, ClusterView, SchemeKind};
use fish::hashring::HashRing;
use fish::metrics::Histogram;
use fish::sketch::{CountMin, SpaceSaving};
use fish::testing::prop_check;

#[test]
fn prop_every_scheme_routes_to_alive_worker() {
    prop_check("route targets alive worker", 60, |g| {
        let workers_n = g.usize_in(1..40);
        let kind = *g.choose(&SchemeKind::all());
        let mut cfg = Config::default();
        cfg.workers = workers_n;
        let mut grouper = make_kind(kind, &cfg, 0);
        let ids: Vec<usize> = (0..workers_n).collect();
        let times: Vec<f64> = (0..workers_n).map(|_| 500.0 + g.f64_in(0.0, 1_000.0)).collect();
        let n = g.usize_in(1..500);
        for i in 0..n {
            let key = g.u64_in(0..50);
            let view = ClusterView {
                now: i as u64 * 10,
                workers: &ids,
                per_tuple_time: &times,
                n_slots: workers_n,
            };
            let w = grouper.route(key, &view);
            if !ids.contains(&w) {
                return false;
            }
        }
        true
    });
}

#[test]
fn prop_fg_is_a_function_of_key() {
    prop_check("FG: same key -> same worker", 40, |g| {
        let n = g.usize_in(1..64);
        let mut cfg = Config::default();
        cfg.workers = n;
        let mut grouper = make_kind(SchemeKind::Field, &cfg, 0);
        let ids: Vec<usize> = (0..n).collect();
        let times = vec![1.0; n];
        let view = ClusterView { now: 0, workers: &ids, per_tuple_time: &times, n_slots: n };
        let key = g.u64();
        let w1 = grouper.route(key, &view);
        (0..10).all(|_| grouper.route(key, &view) == w1)
    });
}

#[test]
fn prop_pkg_replication_bounded_by_two() {
    prop_check("PKG: ≤2 workers per key", 30, |g| {
        let n = g.usize_in(2..64);
        let mut cfg = Config::default();
        cfg.workers = n;
        let mut grouper = make_kind(SchemeKind::Pkg, &cfg, 0);
        let ids: Vec<usize> = (0..n).collect();
        let times = vec![1.0; n];
        let view = ClusterView { now: 0, workers: &ids, per_tuple_time: &times, n_slots: n };
        let key = g.u64_in(0..1_000);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..g.usize_in(1..300) {
            seen.insert(grouper.route(key, &view));
        }
        seen.len() <= 2
    });
}

#[test]
fn prop_hashring_monotone_under_removal() {
    prop_check("ring removal only remaps victim's keys", 30, |g| {
        let n = g.usize_in(3..24);
        let vnodes = g.usize_in(8..96);
        let mut ring = HashRing::new(&(0..n).collect::<Vec<_>>(), vnodes);
        let victim = g.usize_in(0..n);
        let keys: Vec<u64> = (0..200).map(|_| g.u64()).collect();
        let before: Vec<usize> = keys.iter().map(|&k| ring.owner(k).unwrap()).collect();
        ring.remove_worker(victim);
        keys.iter().zip(&before).all(|(&k, &b)| {
            let now = ring.owner(k).unwrap();
            if b == victim { now != victim } else { now == b }
        })
    });
}

#[test]
fn prop_hashring_candidates_distinct_and_alive() {
    prop_check("ring candidates distinct + alive", 40, |g| {
        let n = g.usize_in(1..32);
        let ring = HashRing::new(&(0..n).collect::<Vec<_>>(), 32);
        let d = g.usize_in(1..40);
        let key = g.u64();
        let c = ring.candidates(key, d);
        let set: std::collections::HashSet<_> = c.iter().collect();
        c.len() == d.min(n) && set.len() == c.len() && c.iter().all(|w| *w < n)
    });
}

#[test]
fn prop_spacesaving_never_underestimates_tracked() {
    prop_check("SpaceSaving over-estimates", 30, |g| {
        let cap = g.usize_in(4..64);
        let mut ss = SpaceSaving::new(cap);
        let mut truth: std::collections::HashMap<u64, u64> = Default::default();
        for _ in 0..g.usize_in(100..3_000) {
            let k = g.u64_in(0..200);
            ss.observe(k);
            *truth.entry(k).or_insert(0) += 1;
        }
        // SpaceSaving guarantees estimate >= truth for tracked keys
        // (evicted-and-reinserted keys inherit the min count, which is
        // itself an upper bound on anything it displaced).
        let entries: Vec<(u64, f64)> = ss.iter().collect();
        entries
            .into_iter()
            .all(|(k, c)| c + 1e-9 >= truth.get(&k).copied().unwrap_or(0) as f64)
    });
}

#[test]
fn prop_spacesaving_capacity_invariant() {
    prop_check("SpaceSaving |K| <= K_max", 30, |g| {
        let cap = g.usize_in(1..128);
        let mut ss = SpaceSaving::new(cap);
        for _ in 0..g.usize_in(1..2_000) {
            ss.observe(g.u64_in(0..10_000));
        }
        ss.len() <= cap
    });
}

#[test]
fn prop_countmin_upper_bound_and_decay() {
    prop_check("CMS estimate >= truth; decay scales", 25, |g| {
        let depth = g.usize_in(1..5);
        let width = 1 << g.usize_in(5..10);
        let mut cm = CountMin::new(depth, width);
        let mut truth: std::collections::HashMap<u64, u32> = Default::default();
        for _ in 0..g.usize_in(10..2_000) {
            let k = g.u64_in(0..500);
            cm.add(k);
            *truth.entry(k).or_insert(0) += 1;
        }
        if !truth.iter().all(|(&k, &c)| cm.estimate(k) >= c as f32) {
            return false;
        }
        let probe = *truth.keys().next().unwrap();
        let before = cm.estimate(probe);
        cm.decay(0.5);
        (cm.estimate(probe) - before * 0.5).abs() < 1e-3
    });
}

#[test]
fn prop_histogram_quantiles_ordered_and_bounded() {
    prop_check("histogram quantile ordering", 40, |g| {
        let mut h = Histogram::new();
        let n = g.usize_in(1..2_000);
        let mut max = 0u64;
        for _ in 0..n {
            let v = g.u64_in(0..10_000_000);
            max = max.max(v);
            h.record(v);
        }
        let q50 = h.quantile(0.5);
        let q95 = h.quantile(0.95);
        let q99 = h.quantile(0.99);
        q50 <= q95 && q95 <= q99 && q99 <= h.max() && h.max() == max
    });
}

#[test]
fn prop_route_batch_identical_to_sequential_route() {
    // The batch-first API contract: for EVERY scheme, `route_batch` must
    // be element-wise identical to sequential `route` calls under the
    // same view — across random keys, worker churn, and batch sizes
    // 1 / 7 / 1024 (sub-single, prime-stride, super-batch).
    const SLOTS: usize = 40;
    let kinds = [
        SchemeKind::Shuffle,
        SchemeKind::Field,
        SchemeKind::Pkg,
        SchemeKind::DChoices,
        SchemeKind::WChoices,
        SchemeKind::Fish,
        SchemeKind::Rebalance,
    ];
    prop_check("route_batch == sequential route", 60, |g| {
        let kind = *g.choose(&kinds);
        let batch = *g.choose(&[1usize, 7, 1024]);
        let mut cfg = Config::default();
        cfg.workers = g.usize_in(1..24);
        let mut seq_grouper = make_kind(kind, &cfg, 0);
        let mut batch_grouper = make_kind(kind, &cfg, 0);
        let times: Vec<f64> = (0..SLOTS).map(|_| 500.0 + g.f64_in(0.0, 1_000.0)).collect();
        let mut alive: Vec<usize> = (0..cfg.workers).collect();

        for step in 0..g.usize_in(1..5) {
            // random membership churn (keep at least one worker alive)
            if g.bool(0.4) {
                if g.bool(0.5) && alive.len() > 1 {
                    let idx = g.usize_in(0..alive.len());
                    alive.remove(idx);
                } else {
                    let new = g.usize_in(0..SLOTS);
                    if !alive.contains(&new) {
                        alive.push(new);
                        alive.sort_unstable();
                    }
                }
            }
            let view = ClusterView {
                now: step as u64 * 1_000,
                workers: &alive,
                per_tuple_time: &times,
                n_slots: SLOTS,
            };
            seq_grouper.on_membership_change(&view);
            batch_grouper.on_membership_change(&view);

            let n = g.usize_in(1..600);
            let keys: Vec<u64> = (0..n)
                .map(|_| if g.bool(0.3) { g.u64_in(0..8) } else { g.u64_in(0..5_000) })
                .collect();

            let seq: Vec<usize> = keys.iter().map(|&k| seq_grouper.route(k, &view)).collect();
            let mut got = vec![0usize; n];
            let mut off = 0;
            for chunk in keys.chunks(batch) {
                batch_grouper.route_batch(chunk, &mut got[off..off + chunk.len()], &view);
                off += chunk.len();
            }
            if got != seq {
                return false;
            }
        }
        true
    });
}

#[test]
fn prop_fish_total_routing_under_random_membership() {
    prop_check("FISH routes correctly under churn", 20, |g| {
        let mut cfg = Config::default();
        cfg.workers = 16;
        let mut grouper = make_kind(SchemeKind::Fish, &cfg, 0);
        let times = vec![1_000.0; 24];
        let mut alive: Vec<usize> = (0..16).collect();
        for step in 0..g.usize_in(2..8) {
            // random membership change
            if g.bool(0.5) && alive.len() > 2 {
                let idx = g.usize_in(0..alive.len());
                alive.remove(idx);
            } else {
                let new = g.usize_in(0..24);
                if !alive.contains(&new) {
                    alive.push(new);
                    alive.sort_unstable();
                }
            }
            let view = ClusterView {
                now: step as u64 * 1_000,
                workers: &alive,
                per_tuple_time: &times,
                n_slots: 24,
            };
            grouper.on_membership_change(&view);
            for i in 0..200 {
                let view = ClusterView {
                    now: step as u64 * 1_000 + i,
                    workers: &alive,
                    per_tuple_time: &times,
                    n_slots: 24,
                };
                let w = grouper.route(g.u64_in(0..100), &view);
                if !alive.contains(&w) {
                    return false;
                }
            }
        }
        true
    });
}
