//! Integration over the threaded runtime engine (the Storm stand-in):
//! multi-source multi-worker deployments with backpressure and churn in
//! worker capacity.

use fish::config::Config;
use fish::coordinator::{make_kind, Grouper, SchemeKind};
use fish::engine::rt::{run, RtOptions};
use fish::workload::materialise;
use std::sync::Arc;

fn trace(tuples: usize, workload: &str, z: f64) -> Arc<fish::workload::Trace> {
    let mut gen = fish::workload::by_name(workload, tuples, z, 11);
    Arc::new(materialise(gen.as_mut(), 0))
}

#[test]
fn deploy_exactly_once_accounting_across_schemes() {
    let t = trace(30_000, "zf", 1.5);
    for kind in SchemeKind::all() {
        let mut cfg = Config::default();
        cfg.workers = 8;
        cfg.interval = 1_000_000;
        let sources: Vec<Box<dyn Grouper>> =
            (0..4).map(|s| make_kind(kind, &cfg, s)).collect();
        let r = run(&t, sources, 8, &RtOptions::default());
        assert_eq!(r.worker_counts.iter().sum::<u64>(), 30_000, "{kind}");
        assert_eq!(r.latency.count(), 30_000, "{kind}");
        assert!(r.entries >= r.distinct_keys, "{kind}");
    }
}

#[test]
fn deploy_load_distribution_matches_paper_shape() {
    // Wall-clock latency ordering needs real parallelism (this CI host
    // has one core, so the cluster's aggregate capacity equals a single
    // worker's — the simulator carries the paper's latency figures).
    // The threaded engine still must exhibit the *routing* shape:
    // FG concentrates the hot key on one worker, SG spreads evenly, and
    // FISH stays near SG's balance at far lower replication than SG.
    let t = trace(60_000, "zf", 1.8);
    let run_kind = |kind: SchemeKind| {
        let mut cfg = Config::default();
        cfg.workers = 16;
        cfg.interval = 1_000_000;
        let sources: Vec<Box<dyn Grouper>> =
            (0..4).map(|s| make_kind(kind, &cfg, s)).collect();
        run(&t, sources, 16, &RtOptions::default())
    };
    let sg = run_kind(SchemeKind::Shuffle);
    let fg = run_kind(SchemeKind::Field);
    let fish = run_kind(SchemeKind::Fish);
    let imb = |r: &fish::engine::rt::RtResult| {
        fish::metrics::Imbalance::of_counts(&r.worker_counts).relative
    };
    assert!(imb(&sg) < 0.05, "SG imbalance {}", imb(&sg));
    assert!(imb(&fg) > 1.0, "FG should concentrate load, got {}", imb(&fg));
    assert!(imb(&fish) < 0.6, "FISH imbalance {}", imb(&fish));
    let fish_over = fish.memory_normalized() - 1.0;
    let sg_over = sg.memory_normalized() - 1.0;
    assert!(
        fish_over < sg_over * 0.5,
        "FISH replication overhead {fish_over} vs SG {sg_over}"
    );
}

#[test]
fn deploy_throughput_positive_and_consistent() {
    let t = trace(40_000, "mt", 1.5);
    let mut cfg = Config::default();
    cfg.workers = 8;
    let sources: Vec<Box<dyn Grouper>> =
        (0..2).map(|s| make_kind(SchemeKind::Fish, &cfg, s)).collect();
    let r = run(&t, sources, 8, &RtOptions::default());
    let implied = r.worker_counts.iter().sum::<u64>() as f64 / (r.wall_ns as f64 / 1e9);
    assert!((r.throughput - implied).abs() / implied < 0.01);
}

#[test]
fn deploy_paced_sources_respect_interarrival() {
    let t = trace(5_000, "zf", 1.2);
    let mut cfg = Config::default();
    cfg.workers = 4;
    let sources: Vec<Box<dyn Grouper>> =
        (0..2).map(|s| make_kind(SchemeKind::Shuffle, &cfg, s)).collect();
    let opts = RtOptions {
        queue_depth: 1024,
        per_tuple_ns: vec![0.0],
        interarrival_ns: 10_000, // 10µs → ≥50ms total
        ..Default::default()
    };
    let r = run(&t, sources, 4, &opts);
    assert!(
        r.wall_ns >= 45_000_000,
        "paced run finished too fast: {}ns",
        r.wall_ns
    );
}
