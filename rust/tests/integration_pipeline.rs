//! End-to-end integration over the simulator: every scheme × every
//! workload, checking the paper's qualitative claims hold in-system.

use fish::config::Config;
use fish::coordinator::SchemeKind;
use fish::engine::sim::{run_config, SimResult};

fn cfg(scheme: SchemeKind, workload: &str, workers: usize, z: f64) -> Config {
    let mut c = Config::default();
    c.scheme = scheme;
    c.workload = workload.into();
    c.tuples = 120_000;
    c.zipf_z = z;
    c.workers = workers;
    c.sources = 4;
    c.service_ns = 1_000;
    c.interarrival_ns = (c.service_ns / workers as u64).max(1);
    c
}

fn run(scheme: SchemeKind, workload: &str, workers: usize, z: f64) -> SimResult {
    run_config(&cfg(scheme, workload, workers, z))
}

#[test]
fn every_scheme_processes_every_workload() {
    for workload in ["zf", "mt", "am"] {
        for kind in SchemeKind::all() {
            let r = run(kind, workload, 16, 1.4);
            assert_eq!(
                r.worker_counts.iter().sum::<u64>() as usize,
                r.tuples,
                "{kind} on {workload}"
            );
            assert!(r.makespan > 0);
            assert!(r.entries >= r.distinct_keys);
        }
    }
}

#[test]
fn fish_matches_wchoices_execution_at_lower_replication() {
    // The headline comparison (paper Figs. 9/10 + 15): FISH's execution
    // time is at least competitive with W-C on evolving skewed data,
    // while CHK's frequency-proportional ladder replicates strictly less
    // state than W-C's all-workers hot-key treatment.
    let wc = run(SchemeKind::WChoices, "zf", 64, 1.8);
    let fish = run(SchemeKind::Fish, "zf", 64, 1.8);
    let exec_ratio = fish.makespan as f64 / wc.makespan as f64;
    assert!(exec_ratio < 1.05, "FISH/W-C makespan {exec_ratio}");
    assert!(
        fish.entries < wc.entries,
        "FISH entries {} should undercut W-C {}",
        fish.entries,
        wc.entries
    );
}

#[test]
fn fish_tracks_sg_within_paper_bound() {
    // paper: worst case 1.32x on ZF
    for z in [1.0, 1.5, 2.0] {
        let sg = run(SchemeKind::Shuffle, "zf", 32, z);
        let fish = run(SchemeKind::Fish, "zf", 32, z);
        let ratio = fish.makespan as f64 / sg.makespan as f64;
        assert!(ratio < 1.8, "z={z}: FISH/SG makespan {ratio}");
    }
}

#[test]
fn fish_memory_between_fg_and_sg() {
    let sg = run(SchemeKind::Shuffle, "zf", 64, 1.5);
    let fish = run(SchemeKind::Fish, "zf", 64, 1.5);
    assert!(fish.memory_normalized >= 1.0);
    let fish_over = fish.memory_normalized - 1.0;
    let sg_over = sg.memory_normalized - 1.0;
    assert!(
        fish_over < sg_over * 0.5,
        "FISH overhead {fish_over} vs SG {sg_over}"
    );
}

#[test]
fn scheme_gap_grows_with_workers_for_pkg() {
    // paper Fig. 9: PKG-vs-SG ratio worsens as workers scale
    let r16 = {
        let sg = run(SchemeKind::Shuffle, "zf", 16, 1.8);
        let pkg = run(SchemeKind::Pkg, "zf", 16, 1.8);
        pkg.makespan as f64 / sg.makespan as f64
    };
    let r128 = {
        let sg = run(SchemeKind::Shuffle, "zf", 128, 1.8);
        let pkg = run(SchemeKind::Pkg, "zf", 128, 1.8);
        pkg.makespan as f64 / sg.makespan as f64
    };
    assert!(
        r128 > r16,
        "PKG degradation should grow with scale: 16w {r16} vs 128w {r128}"
    );
}

#[test]
fn config_file_drives_simulation() {
    let dir = std::env::temp_dir().join("fish_it_cfg");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("exp.toml");
    std::fs::write(
        &path,
        r#"
[run]
scheme = "fish"
workload = "zf"
tuples = 30000
zipf_z = 1.5
[topology]
workers = 8
sources = 2
"#,
    )
    .unwrap();
    let cfg = Config::from_file(path.to_str().unwrap()).unwrap();
    assert_eq!(cfg.workers, 8);
    let r = run_config(&cfg);
    assert_eq!(r.tuples, 30_000);
}

#[test]
fn latency_histogram_consistent_with_makespan() {
    let r = run(SchemeKind::Fish, "zf", 16, 1.5);
    assert!(r.latency.count() as usize == r.tuples);
    // max latency cannot exceed makespan
    assert!(r.latency.quantile(1.0) <= r.makespan);
    // p50 <= p95 <= p99
    assert!(r.latency.quantile(0.5) <= r.latency.quantile(0.95));
    assert!(r.latency.quantile(0.95) <= r.latency.quantile(0.99));
}
