//! Cross-layer integration: the AOT XLA path (Pallas CMS via PJRT) must
//! agree with the native Rust sketch bit-for-bit, and FISH must produce
//! equivalent routing behaviour on either identifier backend.
//!
//! These tests skip (with a note) when `artifacts/` has not been built —
//! run `make artifacts` first for full coverage.

use fish::config::Config;
use fish::coordinator::{ClusterView, Grouper, SchemeKind};
use fish::sketch::CountMin;

fn artifacts_available() -> bool {
    std::path::Path::new("artifacts/manifest.txt").exists()
}

#[test]
fn xla_cms_bit_equals_native_countmin() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let svc = fish::runtime::XlaEpochService::spawn("artifacts", 256, 1.0).unwrap();
    let n = svc.spec().epoch_len;

    let mut rng = fish::util::Rng::new(77);
    let keys: Vec<u64> = (0..n).map(|_| rng.gen_range(10_000)).collect();
    let cands: Vec<u64> = keys.iter().take(16).copied().collect();

    // native mirror (alpha=1 → no decay, counts comparable 1:1)
    // geometry must match the artifact: read it from the manifest.
    let rtinfo = fish::runtime::Runtime::new("artifacts").unwrap();
    let spec = rtinfo.pick_variant(256).clone();
    let mut native = CountMin::new(spec.depth, spec.width);
    for &k in &keys {
        native.add(k);
    }

    let keys_i32: Vec<i32> = keys.iter().map(|&k| k as u32 as i32).collect();
    let reply = svc.run_epoch(keys_i32, cands.clone()).unwrap();
    for (k, est) in reply.est {
        let want = native.estimate(k);
        assert!(
            (est - want).abs() < 1e-3,
            "key {k}: xla {est} native {want}"
        );
    }
}

#[test]
fn fish_with_xla_identifier_runs_simulation() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let mut cfg = Config::default();
    cfg.workers = 16;
    cfg.sources = 2;
    cfg.tuples = 8_192; // 8 epochs of the n1024 artifact per source
    cfg.identifier = "xla-cms".into();
    cfg.artifacts_dir = "artifacts".into();
    cfg.interarrival_ns = 100;

    let topology = fish::engine::Topology::from_config(&cfg);
    let sources: Vec<Box<dyn Grouper>> = (0..cfg.sources)
        .map(|_| Box::new(fish::runtime::make_fish_xla(&cfg).unwrap()) as Box<dyn Grouper>)
        .collect();
    let mut sim = fish::engine::Simulator::new(topology, sources, cfg.interarrival_ns);
    let mut gen = fish::workload::by_name("zf", cfg.tuples, 1.6, cfg.seed);
    let r = sim.run(gen.as_mut());
    assert_eq!(r.worker_counts.iter().sum::<u64>() as usize, cfg.tuples);
    assert!(r.memory_normalized < 8.0, "xla-FISH memory {}", r.memory_normalized);
}

#[test]
fn xla_and_native_fish_route_hot_keys_similarly() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let mut cfg = Config::default();
    cfg.workers = 16;
    let mut native = fish::coordinator::make_kind(SchemeKind::Fish, &cfg, 0);
    let mut xla = Box::new(fish::runtime::make_fish_xla(&cfg).unwrap()) as Box<dyn Grouper>;

    let ids: Vec<usize> = (0..16).collect();
    let times = vec![1_000.0; 16];
    let mut rng = fish::util::Rng::new(9);
    let mut native_fan = std::collections::HashSet::new();
    let mut xla_fan = std::collections::HashSet::new();
    for i in 0..20_000u64 {
        let k = if rng.gen_bool(0.4) { 5 } else { 100 + rng.gen_range(10_000) };
        let view = ClusterView { now: i, workers: &ids, per_tuple_time: &times, n_slots: 16 };
        let wn = native.route(k, &view);
        let wx = xla.route(k, &view);
        if k == 5 && i > 10_000 {
            native_fan.insert(wn);
            xla_fan.insert(wx);
        }
    }
    // both identifiers must detect the hot key and fan it out broadly
    assert!(native_fan.len() > 4, "native fan-out {}", native_fan.len());
    assert!(xla_fan.len() > 4, "xla fan-out {}", xla_fan.len());
}
