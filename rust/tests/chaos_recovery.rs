//! Exactly-once crash-recovery oracle (docs/RECOVERY.md): scripted
//! kills through the deterministic simulator must leave every
//! transport-invariant output byte-identical to the fault-free run,
//! and the recovery building blocks — the flush sequencer and the
//! shard snapshot — must replay a crashed shard back to the exact
//! pre-crash state through the public API alone. The multi-process
//! half of the story (real SIGKILLs, socket re-dials) runs in CI's
//! chaos-smoke lane via `fish deploy --chaos ... --verify`.

use fish::aggregate::{Count, FlushSequencer, SeqDecision, WindowedMerge};
use fish::config::Config;
use fish::coordinator::{make_scheme, Grouper, SchemeKind};
use fish::engine::{FaultPoint, SimResult, Simulator, Topology};
use fish::transport::FlushMsg;
use fish::workload::by_name;

fn sim_run(scheme: SchemeKind, faults: Vec<FaultPoint>, snapshot_every: u64) -> SimResult {
    let mut cfg = Config::default();
    cfg.scheme = scheme;
    cfg.workers = 8;
    cfg.tuples = 24_000;
    cfg.sources = 2;
    cfg.interarrival_ns = 500;
    let topology = Topology::from_config(&cfg);
    let sources: Vec<Box<dyn Grouper>> =
        (0..cfg.sources).map(|s| make_scheme(&cfg, s)).collect();
    let mut sim = Simulator::new(topology, sources, cfg.interarrival_ns)
        .with_agg_shards(3)
        .with_agg_window(2_000_000)
        .with_faults(faults)
        .with_snapshot_every(snapshot_every);
    let mut gen = by_name("zf", cfg.tuples, 1.5, cfg.seed);
    sim.run(gen.as_mut())
}

#[test]
fn scripted_kills_leave_every_output_byte_identical() {
    for scheme in [SchemeKind::Fish, SchemeKind::Pkg] {
        let clean = sim_run(scheme, Vec::new(), 0);
        assert!(!clean.recovery.any(), "{scheme}: fault-free run must report zero recovery");
        let chaos = sim_run(
            scheme,
            vec![
                FaultPoint::KillWorker { worker: 1, at_tuple: 1_000 },
                FaultPoint::KillShard { shard: 2, at_flush: 4 },
            ],
            4,
        );
        assert_eq!(chaos.merged_counts, clean.merged_counts, "{scheme}: merged counts");
        assert_eq!(chaos.top_k(10), clean.top_k(10), "{scheme}: top-k");
        assert_eq!(chaos.windows.len(), clean.windows.len(), "{scheme}: window count");
        for (a, b) in chaos.windows.iter().zip(&clean.windows) {
            assert_eq!(a.window, b.window, "{scheme}");
            assert_eq!(a.counts, b.counts, "{scheme}: pane {}", b.window);
        }
        assert_eq!(
            chaos.window_stats.panes_retired, clean.window_stats.panes_retired,
            "{scheme}: pane retirements"
        );
        assert_eq!(chaos.worker_counts, clean.worker_counts, "{scheme}: per-worker tuples");
        assert_eq!(chaos.makespan, clean.makespan, "{scheme}: virtual makespan");
        assert!(chaos.recovery.worker_restarts == 1, "{scheme}");
        assert!(chaos.recovery.shard_restarts == 1, "{scheme}");
        assert!(chaos.recovery.replayed_batches > 0, "{scheme}: replay happened");
    }
}

#[test]
fn sequencer_restored_from_snapshot_dedups_the_replayed_log() {
    // a shard's whole life as the protocol sees it: absorb a prefix,
    // snapshot, crash, restore, then receive the FULL log again — the
    // restored cursor must accept exactly the unseen suffix
    let flush = |worker: usize, seq: u64| FlushMsg {
        worker,
        seq,
        emit_ns: seq * 10,
        watermark: seq * 10,
        panes: vec![(0, vec![(worker as u64 + 1, seq + 1)])],
    };
    let log: Vec<FlushMsg> = (0..6u64).map(|s| flush(0, s)).collect();

    let mut first = FlushSequencer::new(1);
    let mut absorbed_before = 0u64;
    for msg in log.iter().take(4) {
        if let SeqDecision::Accept(batch) = first.offer(msg.worker, msg.seq, msg.clone()) {
            absorbed_before += batch.len() as u64;
        }
    }
    assert_eq!(absorbed_before, 4);
    let expected = first.expected_all().to_vec();
    assert_eq!(expected, vec![4]);

    // crash; restore from the snapshot's cursors; replay everything
    let mut second: FlushSequencer<FlushMsg> = FlushSequencer::restore(expected);
    let mut accepted = Vec::new();
    let mut deduped = 0;
    for msg in &log {
        match second.offer(msg.worker, msg.seq, msg.clone()) {
            SeqDecision::Accept(batch) => accepted.extend(batch.into_iter().map(|m| m.seq)),
            SeqDecision::Replayed => deduped += 1,
            SeqDecision::Buffered => panic!("in-order replay never parks"),
        }
    }
    assert_eq!(deduped, 4, "the snapshotted prefix is deduped, not re-applied");
    assert_eq!(accepted, vec![4, 5], "exactly the unseen suffix is absorbed");
}

#[test]
fn merge_state_restored_from_snapshot_replays_to_identical_output() {
    let feed: Vec<(u64, Vec<(u64, u64)>)> = vec![
        (0, vec![(1, 5), (9, 2)]),
        (1, vec![(3, 1), (1, 1)]),
        (2, vec![(7, 4)]),
        (3, vec![(1, 2), (9, 9)]),
    ];
    // the uninterrupted reference
    let mut clean = WindowedMerge::new(Count, 1_000, 8).with_lateness(250);
    for (w, sub) in feed.clone() {
        clean.absorb(w, sub);
        clean.advance(w * 1_000 + 900);
    }
    let reference = clean.finish();

    // crash after two rounds: snapshot, restore into a fresh stage,
    // replay the suffix — retired panes, ledgers and open panes must
    // all converge on the same bytes
    let mut victim = WindowedMerge::new(Count, 1_000, 8).with_lateness(250);
    for (w, sub) in feed.iter().take(2).cloned() {
        victim.absorb(w, sub);
        victim.advance(w * 1_000 + 900);
    }
    let snap = victim.snapshot();
    let mut restored = WindowedMerge::new(Count, 1_000, 8).with_lateness(250);
    restored.restore(snap);
    for (w, sub) in feed.iter().skip(2).cloned() {
        restored.absorb(w, sub);
        restored.advance(w * 1_000 + 900);
    }
    let replayed = restored.finish();

    assert_eq!(replayed.all_time, reference.all_time);
    assert_eq!(replayed.windows.len(), reference.windows.len());
    for (a, b) in replayed.windows.iter().zip(&reference.windows) {
        assert_eq!(a.window, b.window);
        assert_eq!(a.counts, b.counts, "pane {}", b.window);
    }
    assert_eq!(replayed.window_stats.panes_opened, reference.window_stats.panes_opened);
    assert_eq!(replayed.window_stats.panes_retired, reference.window_stats.panes_retired);
    assert_eq!(replayed.window_stats.late_reopens, reference.window_stats.late_reopens);
    assert_eq!(
        replayed.window_stats.late_reopen_mass,
        reference.window_stats.late_reopen_mass
    );
}
