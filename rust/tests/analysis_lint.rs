//! Self-test for the `fish lint` rule engine: the seeded regressions
//! in `rust/tests/fixtures/lint/` must be flagged, and the real tree
//! under `rust/src/` must scan clean (zero findings; every waived
//! map-iteration site is a documented `// lint: sorted-ok` escape).
//!
//! The second half is the repo's own lint gate running inside
//! `cargo test` — CI additionally runs `fish lint` as a standalone
//! blocking job, but a plain test run already refuses new findings.

use std::path::PathBuf;

use fish::analysis::lint_tree;

fn repo_path(rel: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join(rel)
}

#[test]
fn seeded_regressions_are_flagged() {
    let report = lint_tree(&repo_path("rust/tests/fixtures/lint")).expect("scan fixtures");
    assert_eq!(report.files_scanned, 6, "fixture set changed without updating this test");
    assert_eq!(report.suppressions, 0);
    assert_eq!(
        report.findings.len(),
        6,
        "expected exactly the six seeded findings, got: {:#?}",
        report.findings
    );
    // findings are sorted by (file, line, rule)
    let flush = &report.findings[0];
    assert_eq!(flush.rule, "unsorted-map-iteration");
    assert_eq!(flush.file, "aggregate/bad_flush.rs");
    assert_eq!(flush.line, 16);
    assert!(flush.snippet.contains("drain"), "{flush:?}");
    let alloc = &report.findings[1];
    assert_eq!(alloc.rule, "hotpath-alloc");
    assert_eq!(alloc.file, "aggregate/bad_hotpath.rs");
    assert_eq!(alloc.line, 17);
    assert!(alloc.snippet.contains("to_string"), "{alloc:?}");
    let obs = &report.findings[2];
    assert_eq!(obs.rule, "obs-clock");
    assert_eq!(obs.file, "obs/bad_instant.rs");
    assert_eq!(obs.line, 13);
    assert!(obs.snippet.contains("Instant::now"), "{obs:?}");
    let snap = &report.findings[3];
    assert_eq!(snap.rule, "snapshot-exhaustive");
    assert_eq!(snap.file, "state/bad_snapshot.rs");
    assert_eq!(snap.line, 14);
    assert!(snap.snippet.contains("Default::default"), "{snap:?}");
    let credit = &report.findings[4];
    assert_eq!(credit.rule, "relaxed-credit-atomic");
    assert_eq!(credit.file, "transport/bad_credit.rs");
    assert_eq!(credit.line, 15);
    assert!(credit.snippet.contains("Ordering::Relaxed"), "{credit:?}");
    let seq = &report.findings[5];
    assert_eq!(seq.rule, "frame-exhaustive");
    assert_eq!(seq.file, "transport/bad_flush_seq.rs");
    assert_eq!(seq.line, 11);
    assert!(seq.snippet.contains("FlushMsg"), "{seq:?}");
}

#[test]
fn real_tree_scans_clean() {
    let report = lint_tree(&repo_path("rust/src")).expect("scan rust/src");
    assert!(report.files_scanned > 30, "scanned only {} files — wrong root?", report.files_scanned);
    assert!(
        report.findings.is_empty(),
        "lint findings in the real tree:\n{}",
        report
            .findings
            .iter()
            .map(|f| format!("  {f}\n      {}", f.snippet))
            .collect::<Vec<_>>()
            .join("\n")
    );
    // the documented escape sites — `// lint: sorted-ok` at
    // PartialAgg::flush, windowed all-time + rolling snapshots,
    // ShardedAgg::into_sorted, sketch-window top_count; plus
    // `// lint: alloc-ok` at the windowed pane open (combiner clone,
    // once per window). A new suppression needs a justification
    // comment at the site AND a bump here.
    assert_eq!(
        report.suppressions, 6,
        "suppression count changed — audit the new/removed `lint: sorted-ok` / `lint: alloc-ok` site"
    );
}

#[test]
fn json_report_round_trips_the_counts() {
    let report = lint_tree(&repo_path("rust/tests/fixtures/lint")).expect("scan fixtures");
    let json = report.to_json();
    assert!(json.contains("\"files_scanned\":6"), "{json}");
    assert!(json.contains("\"rule\":\"unsorted-map-iteration\""), "{json}");
    assert!(json.contains("\"rule\":\"hotpath-alloc\""), "{json}");
    assert!(json.contains("\"rule\":\"obs-clock\""), "{json}");
    assert!(json.contains("\"rule\":\"snapshot-exhaustive\""), "{json}");
    assert!(json.contains("\"rule\":\"relaxed-credit-atomic\""), "{json}");
    assert!(json.contains("\"rule\":\"frame-exhaustive\""), "{json}");
}
