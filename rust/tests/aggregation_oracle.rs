//! End-to-end aggregation oracle: the two-stage topology's correctness
//! contract, pinned across every scheme and both engines.
//!
//! The reference is the one configuration where no aggregation is ever
//! needed: a single worker under Field Grouping, which trivially holds
//! the exact per-key counts. Every other (scheme, worker-count) pair
//! splits work — and multi-choice schemes split *keys* — so their
//! per-worker counts are partial; the oracle asserts the downstream
//! merge stage reassembles exactly the reference, element for element,
//! on a fixed-seed evolving trace.

use fish::config::Config;
use fish::coordinator::SchemeKind;
use fish::engine::Pipeline;
use fish::Key;

const TUPLES: usize = 40_000;
const SEED: u64 = 1234;
const Z: f64 = 1.5;

fn base(kind: SchemeKind, workers: usize) -> Config {
    let mut cfg = Config::default();
    cfg.scheme = kind;
    cfg.workload = "zf".into();
    cfg.tuples = TUPLES;
    cfg.zipf_z = Z;
    cfg.workers = workers;
    cfg.sources = 3;
    cfg.seed = SEED;
    cfg.service_ns = 1_000;
    cfg.interarrival_ns = (cfg.service_ns / workers as u64).max(1);
    cfg
}

/// The single-worker Field Grouping reference: exact per-key counts
/// with no key splitting anywhere.
fn reference() -> Vec<(Key, u64)> {
    Pipeline::builder()
        .config(base(SchemeKind::Field, 1))
        .build_sim()
        .run()
        .merged_counts
}

#[test]
fn sim_merged_counts_equal_single_worker_reference_for_every_scheme() {
    let reference = reference();
    assert_eq!(reference.iter().map(|&(_, c)| c).sum::<u64>(), TUPLES as u64);
    for kind in SchemeKind::all() {
        let r = Pipeline::builder().config(base(kind, 16)).build_sim().run();
        assert_eq!(
            r.merged_counts, reference,
            "{kind}: merged counts diverge from the single-worker reference"
        );
    }
}

#[test]
fn rt_merged_counts_equal_single_worker_reference_for_every_scheme() {
    // The threaded engine materialises the same fixed-seed trace, so its
    // aggregator must converge to the same exact counts — despite real
    // thread interleaving and wall-clock flush timing.
    let reference = reference();
    for kind in SchemeKind::all() {
        let mut cfg = base(kind, 8);
        cfg.interarrival_ns = 0; // as fast as possible
        let r = Pipeline::builder().config(cfg).per_tuple_ns(vec![0.0]).build_rt().run();
        assert_eq!(
            r.merged, reference,
            "{kind}: rt merged counts diverge from the single-worker reference"
        );
    }
}

#[test]
fn same_seed_produces_identical_merged_output() {
    let run = || Pipeline::builder().config(base(SchemeKind::Fish, 16)).build_sim().run();
    let (a, b) = (run(), run());
    assert_eq!(a.merged_counts, b.merged_counts);
    assert_eq!(a.top_k(20), b.top_k(20));
    assert_eq!(a.agg.flushes, b.agg.flushes);
    assert_eq!(a.agg.messages, b.agg.messages);
    assert_eq!(a.agg.bytes, b.agg.bytes);
}

#[test]
fn flush_cadence_never_changes_the_merged_result() {
    let reference = reference();
    for flush_ms in [0u64, 1, 7, 1_000] {
        let mut cfg = base(SchemeKind::Pkg, 16);
        cfg.agg_flush_ms = flush_ms;
        let r = Pipeline::builder().config(cfg).build_sim().run();
        assert_eq!(r.merged_counts, reference, "flush_ms={flush_ms}");
    }
}

#[test]
fn churn_does_not_lose_or_duplicate_merged_counts() {
    use fish::engine::ChurnEvent;
    let r = Pipeline::builder()
        .config(base(SchemeKind::Fish, 8))
        .churn(vec![
            (10_000, ChurnEvent::Remove(3)),
            (25_000, ChurnEvent::Add(8)),
        ])
        .build_sim()
        .run();
    // workers came and went mid-stream; the merge still accounts for
    // every tuple exactly once
    let reference = reference();
    assert_eq!(r.merged_counts, reference);
}

#[test]
fn top_k_ranking_agrees_between_engines() {
    let sim = Pipeline::builder().config(base(SchemeKind::Fish, 8)).build_sim().run();
    let mut cfg = base(SchemeKind::Fish, 8);
    cfg.interarrival_ns = 0;
    let rt = Pipeline::builder().config(cfg).per_tuple_ns(vec![0.0]).build_rt().run();
    assert_eq!(sim.top_k(10), rt.top_k(10));
}

// ---- sharded aggregation fabric ---------------------------------------
//
// The shard-count dimension of the oracle: for any `--agg_shards`,
// merged counts and exact top-k must be byte-identical to the
// single-aggregator reference on both engines — sharding changes who
// merges, never what is merged.

#[test]
fn sim_merged_counts_are_shard_count_invariant() {
    let reference = reference();
    let ref_top = fish::aggregate::top_k(&reference, 10);
    for shards in [1usize, 2, 7] {
        let mut cfg = base(SchemeKind::Fish, 16);
        cfg.agg_shards = shards;
        let r = Pipeline::builder().config(cfg).build_sim().run();
        assert_eq!(r.merged_counts, reference, "agg_shards={shards}");
        assert_eq!(r.top_k(10), ref_top, "agg_shards={shards}");
        // the per-shard ledgers account for exactly the total traffic
        assert_eq!(r.shard_agg.n_shards(), shards);
        assert_eq!(
            r.shard_agg.per_shard.iter().map(|s| s.messages).sum::<u64>(),
            r.agg.messages,
            "agg_shards={shards}"
        );
        assert!(r.shard_agg.imbalance().relative >= 0.0);
    }
}

#[test]
fn rt_merged_counts_are_shard_count_invariant() {
    // Acceptance criterion: with --agg_shards 4 (and others) on the rt
    // engine, merged counts are byte-identical to --agg_shards 1.
    let reference = reference();
    let ref_top = fish::aggregate::top_k(&reference, 10);
    for shards in [1usize, 2, 4, 7] {
        let mut cfg = base(SchemeKind::Pkg, 8);
        cfg.interarrival_ns = 0;
        cfg.agg_shards = shards;
        let r = Pipeline::builder().config(cfg).per_tuple_ns(vec![0.0]).build_rt().run();
        assert_eq!(r.merged, reference, "agg_shards={shards}");
        assert_eq!(r.top_k(10), ref_top, "agg_shards={shards}");
        assert_eq!(r.shard_agg.n_shards(), shards);
        assert_eq!(
            r.shard_agg.per_shard.iter().map(|s| s.messages).sum::<u64>(),
            r.agg.messages,
            "agg_shards={shards}"
        );
    }
}

#[test]
fn sharded_merge_survives_churn() {
    use fish::engine::ChurnEvent;
    let reference = reference();
    let mut cfg = base(SchemeKind::Fish, 8);
    cfg.agg_shards = 7;
    let r = Pipeline::builder()
        .config(cfg)
        .churn(vec![
            (10_000, ChurnEvent::Remove(3)),
            (25_000, ChurnEvent::Add(8)),
        ])
        .build_sim()
        .run();
    // workers came and went mid-stream; the fabric still accounts for
    // every tuple exactly once, on whichever shard owns each key
    assert_eq!(r.merged_counts, reference);
}

#[test]
fn sharded_runs_are_deterministic_per_shard() {
    let run = || {
        let mut cfg = base(SchemeKind::Fish, 16);
        cfg.agg_shards = 7;
        Pipeline::builder().config(cfg).build_sim().run()
    };
    let (a, b) = (run(), run());
    assert_eq!(a.merged_counts, b.merged_counts);
    assert_eq!(a.shard_agg.n_shards(), b.shard_agg.n_shards());
    // the virtual-time flush scatter is deterministic shard by shard
    for (s, (x, y)) in a.shard_agg.per_shard.iter().zip(&b.shard_agg.per_shard).enumerate() {
        assert_eq!(x.flushes, y.flushes, "shard {s}");
        assert_eq!(x.messages, y.messages, "shard {s}");
        assert_eq!(x.bytes, y.bytes, "shard {s}");
    }
    assert_eq!(a.agg_latency.count(), b.agg_latency.count());
    assert_eq!(a.gather.top(10).top, b.gather.top(10).top);
}

#[test]
fn mid_run_shard_count_change_keeps_exact_counts() {
    // The fabric's elasticity contract, driven directly: reshard the
    // fabric mid-stream (grow and shrink) and the final merged counts
    // stay byte-identical to a fixed single-shard run — deterministic
    // across repeats.
    use fish::aggregate::{Count, PartialAgg, ShardedMerge};
    let mut gen = fish::workload::by_name("zf", TUPLES, Z, SEED);
    let keys: Vec<Key> = (0..TUPLES).map(|i| gen.key_at(i)).collect();
    let run = |schedule: &[(usize, usize)]| {
        // schedule: (tuple index, new shard count)
        let mut fabric = ShardedMerge::new(Count, 3);
        let mut partial = PartialAgg::new(Count);
        let mut next = 0usize;
        for (i, &k) in keys.iter().enumerate() {
            partial.observe(k, 1);
            if (i + 1) % 1_000 == 0 {
                fabric.absorb(partial.flush());
            }
            if next < schedule.len() && schedule[next].0 == i {
                fabric.set_shards(schedule[next].1);
                next += 1;
            }
        }
        fabric.absorb(partial.flush());
        fabric.into_sorted().0
    };
    let fixed = run(&[]);
    let resharded = run(&[(8_000, 6), (20_000, 2), (32_000, 9)]);
    assert_eq!(fixed, resharded);
    assert_eq!(resharded, run(&[(8_000, 6), (20_000, 2), (32_000, 9)]));
    assert_eq!(fixed.iter().map(|&(_, c)| c).sum::<u64>(), TUPLES as u64);
}

// ---- windowed aggregation -------------------------------------------
//
// The windowed half of the oracle: with `--agg_window_ms > 0`, tuples
// land in tumbling panes by *event time* (virtual arrival ns in sim,
// trace emit ns in rt), so per-window merged counts — and per-window
// exact top-k — must be byte-identical to a per-window single-worker
// Field-Grouping reference for every scheme, shard count, flush
// cadence and engine. `agg_window_ms = 0` must reproduce the
// unwindowed results exactly.

/// 500ns inter-arrivals × 40k tuples = 20ms of event time; 2ms panes
/// → 10 windows of exactly 4000 tuples each.
const WIN_INTERARRIVAL_NS: u64 = 500;
const WIN_MS: u64 = 2;
const PANE_TUPLES: usize = (WIN_MS as usize * 1_000_000) / WIN_INTERARRIVAL_NS as usize;

fn windowed_base(kind: SchemeKind, workers: usize) -> Config {
    let mut cfg = base(kind, workers);
    // event time must be identical across worker counts, so the
    // inter-arrival is fixed rather than derived from `workers`
    cfg.interarrival_ns = WIN_INTERARRIVAL_NS;
    cfg.agg_window_ms = WIN_MS;
    cfg
}

/// Per-window single-worker Field Grouping reference: exact per-pane
/// counts with no key splitting anywhere.
fn windowed_reference() -> Vec<fish::aggregate::WindowSnapshot> {
    Pipeline::builder()
        .config(windowed_base(SchemeKind::Field, 1))
        .build_sim()
        .run()
        .windows
}

fn assert_windows_match(
    got: &[fish::aggregate::WindowSnapshot],
    want: &[fish::aggregate::WindowSnapshot],
    what: &str,
) {
    assert_eq!(got.len(), want.len(), "{what}: window count");
    for (g, w) in got.iter().zip(want) {
        assert_eq!(g.window, w.window, "{what}");
        assert_eq!(g.counts, w.counts, "{what}: pane {}", g.window);
        assert_eq!(g.top_k(10), w.top_k(10), "{what}: pane {} top-k", g.window);
    }
}

#[test]
fn sim_windowed_counts_equal_per_window_reference_for_every_scheme() {
    let reference = windowed_reference();
    assert_eq!(reference.len(), 10);
    assert!(reference.iter().all(|w| w.total() == PANE_TUPLES as u64));
    for kind in SchemeKind::all() {
        let r = Pipeline::builder().config(windowed_base(kind, 16)).build_sim().run();
        assert_windows_match(&r.windows, &reference, &format!("{kind}"));
    }
}

#[test]
fn windowed_counts_are_invariant_across_shards_and_flush_cadences() {
    let reference = windowed_reference();
    for shards in [1usize, 2, 7] {
        for flush_ms in [0u64, 1, 7] {
            let mut cfg = windowed_base(SchemeKind::Fish, 16);
            cfg.agg_shards = shards;
            cfg.agg_flush_ms = flush_ms;
            let r = Pipeline::builder().config(cfg).build_sim().run();
            assert_windows_match(
                &r.windows,
                &reference,
                &format!("shards={shards} flush_ms={flush_ms}"),
            );
        }
    }
}

#[test]
fn rt_windowed_counts_equal_the_per_window_reference() {
    // The threaded engine assigns panes by the trace's scheduled emit
    // times — identical to the simulator's virtual arrivals — so its
    // per-window counts must match byte for byte despite real thread
    // interleaving, heuristic watermarks and wall-clock flush timing.
    let reference = windowed_reference();
    for shards in [1usize, 4] {
        let mut cfg = windowed_base(SchemeKind::Pkg, 8);
        cfg.agg_shards = shards;
        let r = Pipeline::builder().config(cfg).per_tuple_ns(vec![0.0]).build_rt().run();
        assert_windows_match(&r.windows, &reference, &format!("rt shards={shards}"));
    }
}

#[test]
fn windowed_counts_survive_churn() {
    // The tentpole invariance list includes churn: a worker removed
    // mid-stream drains its per-pane partials downstream (sim churn
    // path), so per-window counts must still match the reference byte
    // for byte — no pane loses or double-counts a laggard delta.
    use fish::engine::ChurnEvent;
    let reference = windowed_reference();
    let mut cfg = windowed_base(SchemeKind::Fish, 8);
    cfg.agg_shards = 7;
    let r = Pipeline::builder()
        .config(cfg)
        .churn(vec![
            (10_000, ChurnEvent::Remove(3)),
            (25_000, ChurnEvent::Add(8)),
        ])
        .build_sim()
        .run();
    assert_windows_match(&r.windows, &reference, "windowed churn");
}

#[test]
fn agg_window_zero_reproduces_the_unwindowed_results_exactly() {
    let unwindowed = Pipeline::builder().config(base(SchemeKind::Fish, 16)).build_sim().run();
    let mut cfg = base(SchemeKind::Fish, 16);
    cfg.agg_window_ms = 0; // explicit: today's behavior
    let r = Pipeline::builder().config(cfg).build_sim().run();
    assert!(r.windows.is_empty());
    assert_eq!(r.window_stats.panes_retired, 0);
    assert_eq!(r.merged_counts, unwindowed.merged_counts);
    assert_eq!(r.agg.flushes, unwindowed.agg.flushes);
    assert_eq!(r.agg.messages, unwindowed.agg.messages);
    assert_eq!(r.agg.bytes, unwindowed.agg.bytes);
    assert_eq!(r.gather.top(10).top, unwindowed.gather.top(10).top);

    // and windowing never changes the all-time answer
    let windowed = Pipeline::builder().config(windowed_base(SchemeKind::Fish, 16)).build_sim().run();
    let mut alltime = windowed_base(SchemeKind::Fish, 16);
    alltime.agg_window_ms = 0;
    let alltime = Pipeline::builder().config(alltime).build_sim().run();
    assert_eq!(windowed.merged_counts, alltime.merged_counts);
}

#[test]
fn tumbling_panes_match_the_sliding_window_baseline() {
    // Cross-check against sketch/window.rs, the §2.4 window-based
    // counting baseline: with fixed inter-arrivals, a count-based
    // SlidingWindow of exactly one pane's worth of tuples holds
    // precisely pane p's contents the moment pane p's last tuple has
    // been observed — so the engine's tumbling counts must agree with
    // the buffer-everything baseline at every pane boundary.
    use fish::sketch::SlidingWindow;
    let r = Pipeline::builder().config(windowed_base(SchemeKind::Fish, 16)).build_sim().run();
    let mut gen = fish::workload::by_name("zf", TUPLES, Z, SEED);
    let mut sliding = SlidingWindow::new(PANE_TUPLES);
    let mut pane = 0usize;
    for i in 0..TUPLES {
        sliding.observe(gen.key_at(i));
        if (i + 1) % PANE_TUPLES == 0 {
            let w = &r.windows[pane];
            assert_eq!(w.window, pane as u64);
            assert_eq!(w.total(), PANE_TUPLES as u64, "pane {pane}");
            for &(k, c) in &w.counts {
                assert_eq!(c, sliding.count(k), "pane {pane} key {k}");
            }
            pane += 1;
        }
    }
    assert_eq!(pane, r.windows.len(), "every pane cross-checked");
}

#[test]
fn sliding_windows_compose_panes_exactly() {
    let r = Pipeline::builder().config(windowed_base(SchemeKind::Fish, 16)).build_sim().run();
    let slid = fish::aggregate::sliding(&r.windows, 3);
    assert_eq!(slid.len(), r.windows.len());
    for (i, s) in slid.iter().enumerate() {
        // manual merge of the pane span the sliding window claims
        let lo = i.saturating_sub(2);
        let mut truth: std::collections::HashMap<Key, u64> = std::collections::HashMap::new();
        for p in &r.windows[lo..=i] {
            for &(k, c) in &p.counts {
                *truth.entry(k).or_insert(0) += c;
            }
        }
        assert_eq!(s.counts.len(), truth.len(), "window {i}");
        for &(k, c) in &s.counts {
            assert_eq!(c, truth[&k], "window {i} key {k}");
        }
        assert_eq!(s.panes, 3);
    }
}

#[test]
fn windowed_runs_are_deterministic() {
    let run = || {
        let mut cfg = windowed_base(SchemeKind::Fish, 16);
        cfg.agg_shards = 7;
        Pipeline::builder().config(cfg).build_sim().run()
    };
    let (a, b) = (run(), run());
    assert_eq!(a.windows.len(), b.windows.len());
    for (x, y) in a.windows.iter().zip(&b.windows) {
        assert_eq!(x.counts, y.counts, "pane {}", x.window);
        assert_eq!(x.gather.top(10).top, y.gather.top(10).top, "pane {}", x.window);
    }
    assert_eq!(a.window_stats.panes_opened, b.window_stats.panes_opened);
    assert_eq!(a.window_stats.panes_retired, b.window_stats.panes_retired);
    assert_eq!(a.window_stats.max_open_entries, b.window_stats.max_open_entries);
}

// ---- flush-order determinism (the sorted-flush bugfix) ----------------

#[test]
fn gather_output_is_deterministic_at_sketch_capacity() {
    // Regression test for the nondeterministic-flush bug: PartialAgg
    // drained its HashMap in arbitrary per-instance order, and once a
    // SpaceSaving sketch is at capacity, admission depends on arrival
    // order — so identically-fed runs produced different gather
    // rankings. With flush batches sorted by key, two independent runs
    // must agree exactly even with the sketch far over capacity.
    use fish::aggregate::{Count, PartialAgg, TopKGather};
    let run = || {
        let mut gather = TopKGather::new(2, 64); // tiny: 5000 keys ≫ 2×64
        let mut partial = PartialAgg::new(Count);
        for i in 0..5_000u64 {
            // all-tail stream with a few hot keys: eviction churn makes
            // at-capacity admission order-sensitive
            partial.observe(i % 5_000, 1);
            if i % 7 == 0 {
                partial.observe(i % 11, 1);
            }
            if (i + 1) % 1_000 == 0 {
                gather.absorb_batch(&partial.flush());
            }
        }
        gather.absorb_batch(&partial.flush());
        (gather.top(64).top, gather.error_bound())
    };
    let (a_top, a_bound) = run();
    let (b_top, b_bound) = run();
    assert!(a_bound > 0.0, "sketches must actually be at capacity");
    assert_eq!(a_bound, b_bound);
    assert_eq!(a_top, b_top, "identical runs must produce identical gather rankings");
}

#[test]
fn gather_top_k_respects_error_bounds_against_exact_counts() {
    let mut cfg = base(SchemeKind::Fish, 16);
    cfg.agg_shards = 4;
    let r = Pipeline::builder().config(cfg).build_sim().run();
    let exact: std::collections::HashMap<Key, u64> = r.merged_counts.iter().copied().collect();
    let g = r.gather.top(10);
    assert_eq!(g.top.len(), 10);
    for &(k, est) in &g.top {
        let truth = exact[&k] as f64;
        assert!(est >= truth, "key {k}: estimate {est} under exact {truth}");
        assert!(
            est <= truth + g.error_bound + 1e-9,
            "key {k}: estimate {est} exceeds exact {truth} + bound {}",
            g.error_bound
        );
    }
    // the rank-error-bound statement itself: whatever key the gather
    // ranks first is within error_bound of the true hottest key's count
    let true_top = r.top_k(1)[0].1 as f64;
    let gathered_top_truth = exact[&g.top[0].0] as f64;
    assert!(
        gathered_top_truth + g.error_bound + 1e-9 >= true_top,
        "gathered top key's exact count {gathered_top_truth} not within bound {} of {true_top}",
        g.error_bound
    );
}
