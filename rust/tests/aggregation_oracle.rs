//! End-to-end aggregation oracle: the two-stage topology's correctness
//! contract, pinned across every scheme and both engines.
//!
//! The reference is the one configuration where no aggregation is ever
//! needed: a single worker under Field Grouping, which trivially holds
//! the exact per-key counts. Every other (scheme, worker-count) pair
//! splits work — and multi-choice schemes split *keys* — so their
//! per-worker counts are partial; the oracle asserts the downstream
//! merge stage reassembles exactly the reference, element for element,
//! on a fixed-seed evolving trace.

use fish::config::Config;
use fish::coordinator::SchemeKind;
use fish::engine::Pipeline;
use fish::Key;

const TUPLES: usize = 40_000;
const SEED: u64 = 1234;
const Z: f64 = 1.5;

fn base(kind: SchemeKind, workers: usize) -> Config {
    let mut cfg = Config::default();
    cfg.scheme = kind;
    cfg.workload = "zf".into();
    cfg.tuples = TUPLES;
    cfg.zipf_z = Z;
    cfg.workers = workers;
    cfg.sources = 3;
    cfg.seed = SEED;
    cfg.service_ns = 1_000;
    cfg.interarrival_ns = (cfg.service_ns / workers as u64).max(1);
    cfg
}

/// The single-worker Field Grouping reference: exact per-key counts
/// with no key splitting anywhere.
fn reference() -> Vec<(Key, u64)> {
    Pipeline::builder()
        .config(base(SchemeKind::Field, 1))
        .build_sim()
        .run()
        .merged_counts
}

#[test]
fn sim_merged_counts_equal_single_worker_reference_for_every_scheme() {
    let reference = reference();
    assert_eq!(reference.iter().map(|&(_, c)| c).sum::<u64>(), TUPLES as u64);
    for kind in SchemeKind::all() {
        let r = Pipeline::builder().config(base(kind, 16)).build_sim().run();
        assert_eq!(
            r.merged_counts, reference,
            "{kind}: merged counts diverge from the single-worker reference"
        );
    }
}

#[test]
fn rt_merged_counts_equal_single_worker_reference_for_every_scheme() {
    // The threaded engine materialises the same fixed-seed trace, so its
    // aggregator must converge to the same exact counts — despite real
    // thread interleaving and wall-clock flush timing.
    let reference = reference();
    for kind in SchemeKind::all() {
        let mut cfg = base(kind, 8);
        cfg.interarrival_ns = 0; // as fast as possible
        let r = Pipeline::builder().config(cfg).per_tuple_ns(vec![0.0]).build_rt().run();
        assert_eq!(
            r.merged, reference,
            "{kind}: rt merged counts diverge from the single-worker reference"
        );
    }
}

#[test]
fn same_seed_produces_identical_merged_output() {
    let run = || Pipeline::builder().config(base(SchemeKind::Fish, 16)).build_sim().run();
    let (a, b) = (run(), run());
    assert_eq!(a.merged_counts, b.merged_counts);
    assert_eq!(a.top_k(20), b.top_k(20));
    assert_eq!(a.agg.flushes, b.agg.flushes);
    assert_eq!(a.agg.messages, b.agg.messages);
    assert_eq!(a.agg.bytes, b.agg.bytes);
}

#[test]
fn flush_cadence_never_changes_the_merged_result() {
    let reference = reference();
    for flush_ms in [0u64, 1, 7, 1_000] {
        let mut cfg = base(SchemeKind::Pkg, 16);
        cfg.agg_flush_ms = flush_ms;
        let r = Pipeline::builder().config(cfg).build_sim().run();
        assert_eq!(r.merged_counts, reference, "flush_ms={flush_ms}");
    }
}

#[test]
fn churn_does_not_lose_or_duplicate_merged_counts() {
    use fish::engine::ChurnEvent;
    let r = Pipeline::builder()
        .config(base(SchemeKind::Fish, 8))
        .churn(vec![
            (10_000, ChurnEvent::Remove(3)),
            (25_000, ChurnEvent::Add(8)),
        ])
        .build_sim()
        .run();
    // workers came and went mid-stream; the merge still accounts for
    // every tuple exactly once
    let reference = reference();
    assert_eq!(r.merged_counts, reference);
}

#[test]
fn top_k_ranking_agrees_between_engines() {
    let sim = Pipeline::builder().config(base(SchemeKind::Fish, 8)).build_sim().run();
    let mut cfg = base(SchemeKind::Fish, 8);
    cfg.interarrival_ns = 0;
    let rt = Pipeline::builder().config(cfg).per_tuple_ns(vec![0.0]).build_rt().run();
    assert_eq!(sim.top_k(10), rt.top_k(10));
}
