//! End-to-end aggregation oracle: the two-stage topology's correctness
//! contract, pinned across every scheme and both engines.
//!
//! The reference is the one configuration where no aggregation is ever
//! needed: a single worker under Field Grouping, which trivially holds
//! the exact per-key counts. Every other (scheme, worker-count) pair
//! splits work — and multi-choice schemes split *keys* — so their
//! per-worker counts are partial; the oracle asserts the downstream
//! merge stage reassembles exactly the reference, element for element,
//! on a fixed-seed evolving trace.

use fish::config::Config;
use fish::coordinator::SchemeKind;
use fish::engine::Pipeline;
use fish::Key;

const TUPLES: usize = 40_000;
const SEED: u64 = 1234;
const Z: f64 = 1.5;

fn base(kind: SchemeKind, workers: usize) -> Config {
    let mut cfg = Config::default();
    cfg.scheme = kind;
    cfg.workload = "zf".into();
    cfg.tuples = TUPLES;
    cfg.zipf_z = Z;
    cfg.workers = workers;
    cfg.sources = 3;
    cfg.seed = SEED;
    cfg.service_ns = 1_000;
    cfg.interarrival_ns = (cfg.service_ns / workers as u64).max(1);
    cfg
}

/// The single-worker Field Grouping reference: exact per-key counts
/// with no key splitting anywhere.
fn reference() -> Vec<(Key, u64)> {
    Pipeline::builder()
        .config(base(SchemeKind::Field, 1))
        .build_sim()
        .run()
        .merged_counts
}

#[test]
fn sim_merged_counts_equal_single_worker_reference_for_every_scheme() {
    let reference = reference();
    assert_eq!(reference.iter().map(|&(_, c)| c).sum::<u64>(), TUPLES as u64);
    for kind in SchemeKind::all() {
        let r = Pipeline::builder().config(base(kind, 16)).build_sim().run();
        assert_eq!(
            r.merged_counts, reference,
            "{kind}: merged counts diverge from the single-worker reference"
        );
    }
}

#[test]
fn rt_merged_counts_equal_single_worker_reference_for_every_scheme() {
    // The threaded engine materialises the same fixed-seed trace, so its
    // aggregator must converge to the same exact counts — despite real
    // thread interleaving and wall-clock flush timing.
    let reference = reference();
    for kind in SchemeKind::all() {
        let mut cfg = base(kind, 8);
        cfg.interarrival_ns = 0; // as fast as possible
        let r = Pipeline::builder().config(cfg).per_tuple_ns(vec![0.0]).build_rt().run();
        assert_eq!(
            r.merged, reference,
            "{kind}: rt merged counts diverge from the single-worker reference"
        );
    }
}

#[test]
fn same_seed_produces_identical_merged_output() {
    let run = || Pipeline::builder().config(base(SchemeKind::Fish, 16)).build_sim().run();
    let (a, b) = (run(), run());
    assert_eq!(a.merged_counts, b.merged_counts);
    assert_eq!(a.top_k(20), b.top_k(20));
    assert_eq!(a.agg.flushes, b.agg.flushes);
    assert_eq!(a.agg.messages, b.agg.messages);
    assert_eq!(a.agg.bytes, b.agg.bytes);
}

#[test]
fn flush_cadence_never_changes_the_merged_result() {
    let reference = reference();
    for flush_ms in [0u64, 1, 7, 1_000] {
        let mut cfg = base(SchemeKind::Pkg, 16);
        cfg.agg_flush_ms = flush_ms;
        let r = Pipeline::builder().config(cfg).build_sim().run();
        assert_eq!(r.merged_counts, reference, "flush_ms={flush_ms}");
    }
}

#[test]
fn churn_does_not_lose_or_duplicate_merged_counts() {
    use fish::engine::ChurnEvent;
    let r = Pipeline::builder()
        .config(base(SchemeKind::Fish, 8))
        .churn(vec![
            (10_000, ChurnEvent::Remove(3)),
            (25_000, ChurnEvent::Add(8)),
        ])
        .build_sim()
        .run();
    // workers came and went mid-stream; the merge still accounts for
    // every tuple exactly once
    let reference = reference();
    assert_eq!(r.merged_counts, reference);
}

#[test]
fn top_k_ranking_agrees_between_engines() {
    let sim = Pipeline::builder().config(base(SchemeKind::Fish, 8)).build_sim().run();
    let mut cfg = base(SchemeKind::Fish, 8);
    cfg.interarrival_ns = 0;
    let rt = Pipeline::builder().config(cfg).per_tuple_ns(vec![0.0]).build_rt().run();
    assert_eq!(sim.top_k(10), rt.top_k(10));
}

// ---- sharded aggregation fabric ---------------------------------------
//
// The shard-count dimension of the oracle: for any `--agg_shards`,
// merged counts and exact top-k must be byte-identical to the
// single-aggregator reference on both engines — sharding changes who
// merges, never what is merged.

#[test]
fn sim_merged_counts_are_shard_count_invariant() {
    let reference = reference();
    let ref_top = fish::aggregate::top_k(&reference, 10);
    for shards in [1usize, 2, 7] {
        let mut cfg = base(SchemeKind::Fish, 16);
        cfg.agg_shards = shards;
        let r = Pipeline::builder().config(cfg).build_sim().run();
        assert_eq!(r.merged_counts, reference, "agg_shards={shards}");
        assert_eq!(r.top_k(10), ref_top, "agg_shards={shards}");
        // the per-shard ledgers account for exactly the total traffic
        assert_eq!(r.shard_agg.n_shards(), shards);
        assert_eq!(
            r.shard_agg.per_shard.iter().map(|s| s.messages).sum::<u64>(),
            r.agg.messages,
            "agg_shards={shards}"
        );
        assert!(r.shard_agg.imbalance().relative >= 0.0);
    }
}

#[test]
fn rt_merged_counts_are_shard_count_invariant() {
    // Acceptance criterion: with --agg_shards 4 (and others) on the rt
    // engine, merged counts are byte-identical to --agg_shards 1.
    let reference = reference();
    let ref_top = fish::aggregate::top_k(&reference, 10);
    for shards in [1usize, 2, 4, 7] {
        let mut cfg = base(SchemeKind::Pkg, 8);
        cfg.interarrival_ns = 0;
        cfg.agg_shards = shards;
        let r = Pipeline::builder().config(cfg).per_tuple_ns(vec![0.0]).build_rt().run();
        assert_eq!(r.merged, reference, "agg_shards={shards}");
        assert_eq!(r.top_k(10), ref_top, "agg_shards={shards}");
        assert_eq!(r.shard_agg.n_shards(), shards);
        assert_eq!(
            r.shard_agg.per_shard.iter().map(|s| s.messages).sum::<u64>(),
            r.agg.messages,
            "agg_shards={shards}"
        );
    }
}

#[test]
fn sharded_merge_survives_churn() {
    use fish::engine::ChurnEvent;
    let reference = reference();
    let mut cfg = base(SchemeKind::Fish, 8);
    cfg.agg_shards = 7;
    let r = Pipeline::builder()
        .config(cfg)
        .churn(vec![
            (10_000, ChurnEvent::Remove(3)),
            (25_000, ChurnEvent::Add(8)),
        ])
        .build_sim()
        .run();
    // workers came and went mid-stream; the fabric still accounts for
    // every tuple exactly once, on whichever shard owns each key
    assert_eq!(r.merged_counts, reference);
}

#[test]
fn sharded_runs_are_deterministic_per_shard() {
    let run = || {
        let mut cfg = base(SchemeKind::Fish, 16);
        cfg.agg_shards = 7;
        Pipeline::builder().config(cfg).build_sim().run()
    };
    let (a, b) = (run(), run());
    assert_eq!(a.merged_counts, b.merged_counts);
    assert_eq!(a.shard_agg.n_shards(), b.shard_agg.n_shards());
    // the virtual-time flush scatter is deterministic shard by shard
    for (s, (x, y)) in a.shard_agg.per_shard.iter().zip(&b.shard_agg.per_shard).enumerate() {
        assert_eq!(x.flushes, y.flushes, "shard {s}");
        assert_eq!(x.messages, y.messages, "shard {s}");
        assert_eq!(x.bytes, y.bytes, "shard {s}");
    }
    assert_eq!(a.agg_latency.count(), b.agg_latency.count());
    assert_eq!(a.gather.top(10).top, b.gather.top(10).top);
}

#[test]
fn mid_run_shard_count_change_keeps_exact_counts() {
    // The fabric's elasticity contract, driven directly: reshard the
    // fabric mid-stream (grow and shrink) and the final merged counts
    // stay byte-identical to a fixed single-shard run — deterministic
    // across repeats.
    use fish::aggregate::{Count, PartialAgg, ShardedMerge};
    let mut gen = fish::workload::by_name("zf", TUPLES, Z, SEED);
    let keys: Vec<Key> = (0..TUPLES).map(|i| gen.key_at(i)).collect();
    let run = |schedule: &[(usize, usize)]| {
        // schedule: (tuple index, new shard count)
        let mut fabric = ShardedMerge::new(Count, 3);
        let mut partial = PartialAgg::new(Count);
        let mut next = 0usize;
        for (i, &k) in keys.iter().enumerate() {
            partial.observe(k, 1);
            if (i + 1) % 1_000 == 0 {
                fabric.absorb(partial.flush());
            }
            if next < schedule.len() && schedule[next].0 == i {
                fabric.set_shards(schedule[next].1);
                next += 1;
            }
        }
        fabric.absorb(partial.flush());
        fabric.into_sorted().0
    };
    let fixed = run(&[]);
    let resharded = run(&[(8_000, 6), (20_000, 2), (32_000, 9)]);
    assert_eq!(fixed, resharded);
    assert_eq!(resharded, run(&[(8_000, 6), (20_000, 2), (32_000, 9)]));
    assert_eq!(fixed.iter().map(|&(_, c)| c).sum::<u64>(), TUPLES as u64);
}

#[test]
fn gather_top_k_respects_error_bounds_against_exact_counts() {
    let mut cfg = base(SchemeKind::Fish, 16);
    cfg.agg_shards = 4;
    let r = Pipeline::builder().config(cfg).build_sim().run();
    let exact: std::collections::HashMap<Key, u64> = r.merged_counts.iter().copied().collect();
    let g = r.gather.top(10);
    assert_eq!(g.top.len(), 10);
    for &(k, est) in &g.top {
        let truth = exact[&k] as f64;
        assert!(est >= truth, "key {k}: estimate {est} under exact {truth}");
        assert!(
            est <= truth + g.error_bound + 1e-9,
            "key {k}: estimate {est} exceeds exact {truth} + bound {}",
            g.error_bound
        );
    }
    // the rank-error-bound statement itself: whatever key the gather
    // ranks first is within error_bound of the true hottest key's count
    let true_top = r.top_k(1)[0].1 as f64;
    let gathered_top_truth = exact[&g.top[0].0] as f64;
    assert!(
        gathered_top_truth + g.error_bound + 1e-9 >= true_top,
        "gathered top key's exact count {gathered_top_truth} not within bound {} of {true_top}",
        g.error_bound
    );
}
