//! Trace-determinism oracle for the `obs` subsystem (ISSUE 9).
//!
//! The simulator's timeline is *virtual-time*: every span and instant
//! is stamped from the discrete-event clock, so two runs of the same
//! config must render byte-identical Chrome-trace JSON — across shard
//! counts, and even under scripted chaos (`FaultPoint` kills). The
//! tests here are the repo-side counterpart of the CI lane that diffs
//! `fish sim --trace-out` outputs (`scripts/check_trace.py` validates
//! the schema; this file pins the semantics).

use fish::config::Config;
use fish::coordinator::{make_scheme, Grouper, SchemeKind};
use fish::engine::{FaultPoint, SimResult, Simulator, Topology};
use fish::obs::{chrome_trace_json, sample};

/// One windowed, traced sim run: PKG over 8 workers, 2ms panes over
/// 15ms of virtual time (mirrors the chaos oracle in `engine::sim`).
fn traced_run(agg_shards: usize, faults: Vec<FaultPoint>, snapshot_every: u64) -> SimResult {
    let mut cfg = Config::default();
    cfg.scheme = SchemeKind::Pkg;
    cfg.workers = 8;
    cfg.tuples = 30_000;
    cfg.sources = 2;
    cfg.interarrival_ns = 500;
    let topology = Topology::from_config(&cfg);
    let sources: Vec<Box<dyn Grouper>> =
        (0..cfg.sources).map(|s| make_scheme(&cfg, s)).collect();
    let mut sim = Simulator::new(topology, sources, cfg.interarrival_ns)
        .with_agg_shards(agg_shards)
        .with_agg_window(2_000_000)
        .with_faults(faults)
        .with_snapshot_every(snapshot_every)
        .with_trace(true);
    let mut gen = fish::workload::by_name("zf", cfg.tuples, 1.5, cfg.seed);
    sim.run(gen.as_mut())
}

#[test]
fn sim_trace_is_byte_identical_across_runs() {
    for shards in [1usize, 2] {
        let a = traced_run(shards, Vec::new(), 0);
        let b = traced_run(shards, Vec::new(), 0);
        let (ja, jb) = (chrome_trace_json(&a.trace_blobs), chrome_trace_json(&b.trace_blobs));
        assert_eq!(ja, jb, "virtual-time trace must be byte-identical (shards={shards})");
        assert!(ja.starts_with("{\"traceEvents\":[\n"), "Chrome-trace shape");
        // both timelines present: main loop (tid 0) and stage two (tid 1)
        assert!(a.trace_blobs.iter().any(|b| b.tid == 0), "main-loop blob missing");
        assert!(a.trace_blobs.iter().any(|b| b.tid == 1), "stage-two blob missing");
        for name in ["route_batch", "worker_absorb", "flush_send", "merge_absorb", "gather"] {
            assert!(ja.contains(&format!("\"name\":\"{name}\"")), "missing event {name}");
        }
        // telemetry sampled on the virtual grid is deterministic too
        assert!(!a.samples.is_empty(), "sampler never fired");
        assert_eq!(a.samples, b.samples);
        assert_eq!(sample::jsonl(&a.samples), sample::jsonl(&b.samples));
    }
}

#[test]
fn chaos_trace_is_byte_identical_and_records_recovery() {
    let faults = || {
        vec![
            FaultPoint::KillWorker { worker: 2, at_tuple: 1_000 },
            FaultPoint::KillShard { shard: 1, at_flush: 3 },
            FaultPoint::KillShard { shard: 0, at_flush: 5 },
        ]
    };
    let a = traced_run(3, faults(), 4);
    let b = traced_run(3, faults(), 4);
    let (ja, jb) = (chrome_trace_json(&a.trace_blobs), chrome_trace_json(&b.trace_blobs));
    assert_eq!(ja, jb, "chaos trace must still be byte-identical");
    // every recovery event class shows up on the timeline
    for name in ["kill_worker", "replay_tuples", "kill_shard", "snapshot", "restore"] {
        assert!(ja.contains(&format!("\"name\":\"{name}\"")), "missing recovery event {name}");
    }
}

#[test]
fn flush_chain_is_complete() {
    // causal chain keyed by (worker, shard, seq): every flush_send must
    // land as exactly one merge_absorb — or flush_dedup under chaos
    let r = traced_run(2, Vec::new(), 0);
    let mut sent: Vec<u64> = Vec::new();
    let mut landed: Vec<u64> = Vec::new();
    for blob in &r.trace_blobs {
        for e in &blob.events {
            match e.name.as_str() {
                "flush_send" => sent.push(e.seq),
                "merge_absorb" | "flush_dedup" => landed.push(e.seq),
                _ => {}
            }
        }
    }
    assert!(!sent.is_empty(), "no flush_send events recorded");
    sent.sort_unstable();
    landed.sort_unstable();
    assert_eq!(sent, landed, "flush_send chain ids must pair with merge_absorb/flush_dedup");
    sent.dedup();
    assert_eq!(sent.len(), landed.len(), "chain ids must be unique per (worker, shard, seq)");
}

#[test]
fn tracing_never_changes_results_and_is_off_by_default() {
    let traced = traced_run(2, Vec::new(), 0);
    let mut cfg = Config::default();
    cfg.scheme = SchemeKind::Pkg;
    cfg.workers = 8;
    cfg.tuples = 30_000;
    cfg.sources = 2;
    cfg.interarrival_ns = 500;
    let topology = Topology::from_config(&cfg);
    let sources: Vec<Box<dyn Grouper>> =
        (0..cfg.sources).map(|s| make_scheme(&cfg, s)).collect();
    let mut sim = Simulator::new(topology, sources, cfg.interarrival_ns)
        .with_agg_shards(2)
        .with_agg_window(2_000_000);
    let mut gen = fish::workload::by_name("zf", cfg.tuples, 1.5, cfg.seed);
    let plain = sim.run(gen.as_mut());

    assert_eq!(traced.merged_counts, plain.merged_counts);
    assert_eq!(traced.worker_counts, plain.worker_counts);
    assert_eq!(traced.makespan, plain.makespan);
    assert_eq!(traced.windows.len(), plain.windows.len());
    // zero-cost-when-disabled contract: a default run records nothing
    assert!(plain.trace_blobs.is_empty());
    assert!(plain.samples.is_empty());
}
