//! Transport subsystem oracle tests: the wire format must round-trip
//! anything the engine can ship, reject corrupted and mis-versioned
//! frames without panicking, and — the headline invariant — the same
//! pipeline must produce byte-identical merged counts, per-window
//! snapshots and exact top-k over loopback channels, UDS streams and
//! TCP streams.

use fish::config::Config;
use fish::engine::rt::RtResult;
use fish::engine::Pipeline;
use fish::transport::wire::{self, FlushMsg, Frame, Msg, WireError};
use fish::util::Rng;
use fish::workload::{by_name, materialise};
use std::sync::Arc;

fn random_msgs(rng: &mut Rng, n: usize) -> Vec<Msg> {
    (0..n)
        .map(|_| Msg {
            key: rng.gen_range(1 << 48),
            emit_ns: rng.gen_range(1 << 60),
            ts: rng.gen_range(1 << 60),
        })
        .collect()
}

fn random_flush(rng: &mut Rng) -> FlushMsg {
    let n_panes = rng.gen_range(4) as usize;
    FlushMsg {
        worker: rng.gen_range(64) as usize,
        seq: rng.gen_range(1 << 32),
        emit_ns: rng.gen_range(1 << 60),
        watermark: rng.gen_range(1 << 60),
        panes: (0..n_panes)
            .map(|_| {
                let n = rng.gen_range(16) as usize;
                let entries = (0..n)
                    .map(|_| (rng.gen_range(1 << 40), rng.gen_range(1 << 30) + 1))
                    .collect();
                (rng.gen_range(1000), entries)
            })
            .collect(),
    }
}

#[test]
fn randomized_frames_round_trip() {
    let mut rng = Rng::new(0xF15);
    let mut buf = Vec::new();
    for round in 0..200 {
        buf.clear();
        let n = rng.gen_range(64) as usize;
        let msgs = random_msgs(&mut rng, n);
        wire::encode_data(&msgs, &mut buf);
        let (frame, used) = wire::decode_frame(&buf).expect("data frame");
        assert_eq!(used, buf.len(), "round {round}");
        assert_eq!(frame, Frame::Data(msgs), "round {round}");

        buf.clear();
        let flush = random_flush(&mut rng);
        wire::encode_flush(&flush, &mut buf);
        let (frame, used) = wire::decode_frame(&buf).expect("flush frame");
        assert_eq!(used, buf.len());
        assert_eq!(frame, Frame::Flush(flush), "round {round}");
    }

    // a watermark-only flush (no panes) is the windowed keep-alive —
    // it must survive the wire like any data-bearing frame
    buf.clear();
    let keepalive =
        FlushMsg { worker: 3, seq: 41, emit_ns: 17, watermark: u64::MAX, panes: Vec::new() };
    wire::encode_flush(&keepalive, &mut buf);
    let (frame, _) = wire::decode_frame(&buf).expect("keep-alive");
    assert_eq!(frame, Frame::Flush(keepalive));

    // back-to-back frames in one buffer decode by consumed offsets
    buf.clear();
    wire::encode_credit(77, &mut buf);
    wire::encode_hello(2, 5, "tcp:127.0.0.1:4099", &mut buf);
    wire::encode_resume(3, 42, &mut buf);
    wire::encode_eof(&mut buf);
    wire::encode_done(&[1, 2, 3], &mut buf);
    let mut off = 0;
    let mut frames = Vec::new();
    while off < buf.len() {
        let (frame, used) = wire::decode_frame(&buf[off..]).expect("stream");
        off += used;
        frames.push(frame);
    }
    assert_eq!(
        frames,
        vec![
            Frame::Credit(77),
            Frame::Hello { role: 2, index: 5, addr: "tcp:127.0.0.1:4099".into() },
            Frame::Resume { worker: 3, next_seq: 42 },
            Frame::Eof,
            Frame::Done(vec![1, 2, 3]),
        ]
    );
}

#[test]
fn truncated_frames_error_cleanly() {
    // one encoded specimen of EVERY frame kind — a new Frame variant
    // without an entry here fails the count check below
    let mut rng = Rng::new(7);
    let mut specimens: Vec<(&str, Vec<u8>)> = Vec::new();
    let mut buf = Vec::new();
    wire::encode_data(&random_msgs(&mut rng, 9), &mut buf);
    specimens.push(("data", buf));
    let mut buf = Vec::new();
    wire::encode_flush(&random_flush(&mut rng), &mut buf);
    specimens.push(("flush", buf));
    let mut buf = Vec::new();
    wire::encode_flush(
        &FlushMsg { worker: 1, seq: 8, emit_ns: 9, watermark: u64::MAX, panes: Vec::new() },
        &mut buf,
    );
    specimens.push(("flush-keepalive", buf));
    let mut buf = Vec::new();
    wire::encode_credit(123, &mut buf);
    specimens.push(("credit", buf));
    let mut buf = Vec::new();
    wire::encode_hello(1, 7, "tcp:127.0.0.1:4099", &mut buf);
    specimens.push(("hello", buf));
    let mut buf = Vec::new();
    wire::encode_eof(&mut buf);
    specimens.push(("eof", buf));
    let mut buf = Vec::new();
    wire::encode_done(&[1, 2, 3, 4], &mut buf);
    specimens.push(("done", buf));
    let mut buf = Vec::new();
    wire::encode_resume(5, 97, &mut buf);
    specimens.push(("resume", buf));
    assert_eq!(specimens.len(), 8, "cover every frame kind (incl. the pane-less flush)");

    let mut scratch = Vec::new();
    for (kind, buf) in &specimens {
        // every strict prefix is an error — never a panic, never a
        // bogus frame, never a silent partial decode
        for cut in 0..buf.len() {
            match wire::decode_frame(&buf[..cut]) {
                Err(WireError::Truncated) => {}
                other => panic!("{kind} prefix {cut}: expected Truncated, got {other:?}"),
            }
        }
        // a Reader over a stream that ends mid-frame reports Truncated
        // at every cut past the empty prefix…
        for cut in 1..buf.len() {
            let mut cursor = std::io::Cursor::new(&buf[..cut]);
            match wire::read_frame(&mut cursor, &mut scratch) {
                Err(WireError::Truncated) => {}
                other => panic!("{kind} stream cut {cut}: expected Truncated, got {other:?}"),
            }
        }
        // …while a clean end-of-stream on a frame boundary is None
        let mut cursor = std::io::Cursor::new(&buf[..0]);
        assert!(
            matches!(wire::read_frame(&mut cursor, &mut scratch), Ok(None)),
            "{kind}: empty stream must be a clean EOF"
        );
        // and the untruncated frame still decodes, consuming every byte
        let (_, used) = wire::decode_frame(buf).expect(kind);
        assert_eq!(used, buf.len(), "{kind}: trailing bytes after decode");
    }
}

#[test]
fn snapshot_codec_rejects_every_truncation() {
    // the shard-snapshot codec shares the wire's primitives and its
    // contract: every strict prefix of a persisted snapshot must come
    // back as Truncated — a crash mid-write can never half-restore
    let mut rng = Rng::new(0x5AFE);
    let mut merge = fish::aggregate::WindowedMerge::new(fish::aggregate::Count, 1_000, 4)
        .with_lateness(500);
    merge.absorb(0, vec![(11, 3), (29, 1)]);
    merge.advance(2_700);
    merge.absorb(2, vec![(11, 2)]);
    let snap = fish::state::ShardSnapshot {
        shard: 2,
        expected_seq: vec![4, 9, 0, 1],
        worker_wm: vec![2_700, 1_000, 0, 2_000],
        merge: merge.snapshot(),
        sketch_entries: vec![(11, 5.0), (29, 1.0)],
        sketch_error: 0.5,
        buffered: vec![random_flush(&mut rng), random_flush(&mut rng)],
        latency: fish::metrics::Histogram::new(),
        recovery: Default::default(),
    };
    let bytes = snap.to_bytes();
    for cut in 0..bytes.len() {
        match fish::state::ShardSnapshot::from_bytes(&bytes[..cut]) {
            Err(WireError::Truncated) => {}
            other => panic!("snapshot prefix {cut}/{}: expected Truncated, got {other:?}",
                bytes.len()),
        }
    }
    let back = fish::state::ShardSnapshot::from_bytes(&bytes).expect("full decode");
    assert_eq!(back.to_bytes(), bytes, "decode → re-encode must be byte-identical");
}

#[test]
fn corrupted_headers_are_rejected() {
    let mut buf = Vec::new();
    wire::encode_credit(1, &mut buf);

    // version byte (offset 4): a future build's frames are refused loudly
    let mut v = buf.clone();
    v[4] = wire::VERSION + 1;
    match wire::decode_frame(&v) {
        Err(WireError::VersionMismatch { got, want }) => {
            assert_eq!(got, wire::VERSION + 1);
            assert_eq!(want, wire::VERSION);
        }
        other => panic!("expected VersionMismatch, got {other:?}"),
    }

    // magic (offset 0..4): junk on the stream is not a frame
    let mut m = buf.clone();
    m[0] = b'X';
    assert!(matches!(wire::decode_frame(&m), Err(WireError::BadMagic)));

    // kind byte (offset 5): unknown frame kinds are refused
    let mut k = buf.clone();
    k[5] = 0xEE;
    assert!(matches!(wire::decode_frame(&k), Err(WireError::BadKind(0xEE))));
}

/// One windowed, sharded, multi-source pipeline over the given lane
/// backend, on a shared trace.
fn run_transport(trace: &Arc<fish::workload::Trace>, transport: &str) -> RtResult {
    let mut cfg = Config::default();
    cfg.scheme = fish::coordinator::SchemeKind::Pkg;
    cfg.workers = 4;
    cfg.sources = 2;
    cfg.agg_shards = 2;
    cfg.agg_window_ms = 1;
    cfg.agg_lateness_ms = 1;
    cfg.interarrival_ns = 500;
    cfg.transport = transport.into();
    Pipeline::builder()
        .config(cfg)
        .trace(Arc::clone(trace))
        .per_tuple_ns(vec![0.0])
        .build_rt()
        .run()
}

// Miri has no sockets or real threads-with-time; the codec tests above
// are the Miri target, the pipeline tests run under the native suite
// and TSan.
#[cfg_attr(miri, ignore)]
#[test]
fn loopback_uds_tcp_produce_identical_results() {
    let mut gen = by_name("zf", 20_000, 1.5, 11);
    let trace = Arc::new(materialise(gen.as_mut(), 500));

    let reference = run_transport(&trace, "loopback");
    assert!(!reference.wire.any(), "loopback serializes nothing");
    assert_eq!(reference.windows.len(), 10, "20k × 500ns = 10 panes of 1ms");

    let mut others = vec![run_transport(&trace, "tcp")];
    #[cfg(unix)]
    others.push(run_transport(&trace, "uds"));
    for r in &others {
        assert_eq!(r.merged, reference.merged);
        assert_eq!(r.top_k(10), reference.top_k(10));
        assert_eq!(r.worker_counts.iter().sum::<u64>(), 20_000);
        assert_eq!(r.windows.len(), reference.windows.len());
        for (a, b) in r.windows.iter().zip(&reference.windows) {
            assert_eq!(a.window, b.window);
            assert_eq!(a.counts, b.counts, "pane {}", b.window);
        }
        // socket lanes really carried the stream: every tuple crossed
        // the wire once, plus the flush entries the shards absorbed
        assert!(r.wire.any());
        assert_eq!(r.wire.tuples_out, 20_000 + r.agg.messages);
        assert_eq!(r.wire.tuples_in, r.wire.tuples_out, "nothing lost in flight");
        assert!(r.wire.bytes_out >= r.wire.tuples_out * wire::MSG_BYTES as u64 / 2);
    }
}

#[cfg_attr(miri, ignore)]
#[test]
fn tiny_credit_windows_still_drain_over_tcp() {
    // queue_depth 2 forces constant credit-frame ping-pong; the run
    // must neither deadlock nor drop tuples
    let mut gen = by_name("zf", 5_000, 1.5, 3);
    let trace = Arc::new(materialise(gen.as_mut(), 0));
    let mut cfg = Config::default();
    cfg.scheme = fish::coordinator::SchemeKind::Shuffle;
    cfg.workers = 3;
    cfg.sources = 2;
    cfg.interarrival_ns = 0;
    cfg.transport = "tcp".into();
    let r = Pipeline::builder()
        .config(cfg)
        .trace(trace)
        .per_tuple_ns(vec![0.0])
        .queue_depth(2)
        .build_rt()
        .run();
    assert_eq!(r.worker_counts.iter().sum::<u64>(), 5_000);
    assert_eq!(r.merged.iter().map(|&(_, c)| c).sum::<u64>(), 5_000);
}
