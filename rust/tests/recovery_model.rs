//! Exhaustive bounded model check of the exactly-once flush/recovery
//! protocol: N workers × M shards, per-lane sequence numbers, the
//! production `FlushSequencer` embedded verbatim in the model states,
//! snapshot-every-K persistence, crash transitions at every protocol
//! step, and the `Resume` + unacked-suffix replay handshake.
//!
//! Proves, over the bounded configs below: exactly-once absorb, no
//! lost flushes, monotone sequencer cursors, snapshot-restore
//! convergence, and quiescence reachability. Each seeded mutation
//! (rust/src/analysis/recovery.rs) must produce its pinned
//! deterministic counterexample — a recovery checker that cannot
//! catch a broken snapshot verifies nothing.
//!
//! The state/transition/depth/final counts are exact graph properties
//! of each configuration, independent of exploration order. See
//! `docs/MODEL.md` for the protocol walkthrough and the bounds.

use fish::analysis::{
    check_recovery, CheckOptions, Counterexample, RecoveryConfig, RecoveryMutation, Violation,
};

fn cfg(
    n_workers: usize,
    n_shards: usize,
    tuples: u64,
    every: u64,
    worker_kills: u32,
    shard_kills: u32,
    mutation: RecoveryMutation,
) -> RecoveryConfig {
    RecoveryConfig {
        n_workers,
        n_shards,
        tuples_per_worker: tuples,
        snapshot_every: every,
        worker_kills,
        shard_kills,
        mutation,
    }
}

/// The bounded configurations the honest protocol must pass, with
/// their exact (states, transitions, depth, finals). Together they
/// cover ≥2 workers × ≥2 shards, snapshot cadences 1, 2 and 3, and a
/// crash budget that lets a worker and a shard die at every protocol
/// step (the 3-worker config trades the shard kill for a wider
/// interleaving fan-out).
const HONEST: &[(usize, usize, u64, u64, u32, u32, (u64, u64, u64, u64))] = &[
    (2, 2, 2, 1, 1, 1, (42_244, 204_476, 26, 576)),
    (2, 2, 3, 2, 1, 1, (71_328, 362_952, 31, 480)),
    (2, 2, 3, 3, 1, 1, (35_508, 186_996, 28, 96)),
    (3, 2, 2, 2, 1, 0, (28_320, 138_064, 25, 512)),
];

#[test]
fn honest_recovery_is_exhaustively_clean_with_pinned_state_spaces() {
    let opts = CheckOptions::default();
    for &(w, s, t, k, wk, sk, (states, transitions, depth, finals)) in HONEST {
        let config = cfg(w, s, t, k, wk, sk, RecoveryMutation::None);
        let stats = check_recovery(&config, &opts)
            .unwrap_or_else(|cx| panic!("violation under {config:?}:\n{}", cx.render()));
        assert_eq!(
            (stats.states, stats.transitions, stats.depth, stats.finals),
            (states, transitions, depth, finals),
            "state space changed for {config:?}"
        );
    }
}

#[test]
fn honest_recovery_terminates() {
    // acyclicity on the full crashy config: every quantity a cycle
    // would need to restore (input, lane cursors, absorb ledgers, the
    // crash budgets) moves monotonically, so every run quiesces
    let opts = CheckOptions { check_termination: true, ..CheckOptions::default() };
    check_recovery(&cfg(2, 2, 2, 1, 1, 1, RecoveryMutation::None), &opts)
        .unwrap_or_else(|cx| panic!("termination check failed:\n{}", cx.render()));
}

/// The four seeded mutations with their pinned counterexamples. Both
/// the violated property and the full shortest trace are asserted —
/// the trace doubles as documentation of how each bug plays out.
fn expect_property(cx: &Counterexample, property: &str, detail: &str) {
    match &cx.violation {
        Violation::Property(p) => {
            assert_eq!(p.property, property, "wrong property:\n{}", cx.render());
            assert_eq!(p.detail, detail, "wrong detail:\n{}", cx.render());
        }
        other => panic!("wrong violation kind: {other}"),
    }
}

#[test]
fn unsynced_snapshot_loses_absorbed_flushes() {
    // the snapshot rename lands but the body never hit disk: the
    // restored shard has the cursors and none of the absorbed state
    let cx = check_recovery(
        &cfg(2, 2, 2, 1, 1, 1, RecoveryMutation::SkipSnapshotFsync),
        &CheckOptions::default(),
    )
    .expect_err("unsynced snapshot must be caught");
    expect_property(
        &cx,
        "no-lost-flush",
        "shard 0 cursor for worker 0 is 1 but seqs 0.. were never absorbed",
    );
    assert_eq!(
        cx.trace,
        vec![
            "w0 folds a tuple",
            "w0 flushes seq 0 to s0",
            "s0 absorbs w0 seq 0",
            "s0 begins snapshot at cursors [1, 0]",
            "s0 commits snapshot",
            "s0 crashes and restores from snapshot",
        ],
        "trace changed:\n{}",
        cx.render()
    );
}

#[test]
fn resume_off_by_one_drops_the_first_unacked_batch() {
    let cx = check_recovery(
        &cfg(2, 2, 2, 1, 1, 1, RecoveryMutation::ResumeOffByOne),
        &CheckOptions::default(),
    )
    .expect_err("off-by-one resume must be caught");
    expect_property(
        &cx,
        "no-lost-flush",
        "quiescent but shard 1 absorbed 0 of 1 batches from worker 0",
    );
    assert_eq!(
        cx.trace,
        vec![
            "w0 folds a tuple",
            "w0 folds a tuple",
            "w0 flushes seq 0 to s0",
            "w0 flushes seq 0 to s1",
            "w1 folds a tuple",
            "w1 folds a tuple",
            "w1 flushes seq 0 to s0",
            "w1 flushes seq 0 to s1",
            "s0 absorbs w0 seq 0",
            "s0 absorbs w1 seq 0",
            "s1 crashes and restores cold",
            "w0 resumes lane to s1, replays from seq 1",
            "w1 resumes lane to s1, replays from seq 1",
        ],
        "trace changed:\n{}",
        cx.render()
    );
}

#[test]
fn replaying_from_the_send_cursor_replays_nothing() {
    // ignoring the Resume answer and trusting the sender's own cursor
    // is indistinguishable from the off-by-one bug at these bounds:
    // both skip exactly the unacked suffix
    let cx = check_recovery(
        &cfg(2, 2, 2, 1, 1, 1, RecoveryMutation::ReplayFromWrongCursor),
        &CheckOptions::default(),
    )
    .expect_err("wrong-cursor replay must be caught");
    expect_property(
        &cx,
        "no-lost-flush",
        "quiescent but shard 1 absorbed 0 of 1 batches from worker 0",
    );
    assert_eq!(cx.trace.len(), 13, "trace changed:\n{}", cx.render());
}

#[test]
fn truncated_dedup_window_double_absorbs_a_replay() {
    // a snapshot that truncates the per-worker cursor vector forgets
    // how far worker 0 got; the replayed seq 1 is absorbed again
    let cx = check_recovery(
        &cfg(2, 2, 3, 1, 1, 1, RecoveryMutation::DedupWindowTruncation),
        &CheckOptions::default(),
    )
    .expect_err("truncated dedup window must be caught");
    expect_property(&cx, "exactly-once-absorb", "shard 0 absorbed worker 0 seq 1 2 times");
    assert_eq!(
        cx.trace,
        vec![
            "w0 folds a tuple",
            "w0 folds a tuple",
            "w0 folds a tuple",
            "w0 flushes seq 0 to s0",
            "w0 flushes seq 0 to s1",
            "w0 flushes seq 1 to s0",
            "s0 absorbs w0 seq 0",
            "s0 absorbs w0 seq 1",
            "s0 begins snapshot at cursors [2, 0]",
            "s0 commits snapshot",
            "s0 crashes and restores from snapshot",
            "w0 resumes lane to s0, replays from seq 1",
            "s0 absorbs w0 seq 1",
        ],
        "trace changed:\n{}",
        cx.render()
    );
}

#[test]
fn counterexamples_are_deterministic_and_round_trip_the_formatter() {
    let opts = CheckOptions::default();
    let config = cfg(2, 2, 2, 1, 1, 1, RecoveryMutation::SkipSnapshotFsync);
    let a = check_recovery(&config, &opts).expect_err("run a");
    let b = check_recovery(&config, &opts).expect_err("run b");
    // byte-stable across runs
    assert_eq!(a.render(), b.render(), "nondeterministic counterexample");
    // and the rendering parses back into exactly its parts
    let (head, trace) = Counterexample::parse(&a.render()).expect("rendered form must parse");
    assert_eq!(head, a.violation.to_string());
    assert_eq!(trace, a.trace);
}
