//! Exhaustive bounded model check of the credit flow-control
//! protocol, plus mutation tests proving the checker actually detects
//! each violation class (a model checker that cannot fail its
//! invariants verifies nothing).
//!
//! See `rust/src/analysis/model.rs` for the protocol model and
//! `docs/DETERMINISM.md` for the rules under check.

use fish::analysis::{check, ModelConfig, ModelStats, Mutation, Violation};

fn cfg(n_senders: usize, window: u32, tuples: u32, chunk: u32, mutation: Mutation) -> ModelConfig {
    ModelConfig { n_senders, window, tuples_per_sender: tuples, chunk, mutation, max_states: 2_000_000 }
}

/// The bounded configurations the honest protocol must pass. Two
/// concurrent senders cover cross-stream interleavings; the deeper
/// single-sender runs cover long grant/flush chains; window==chunk
/// exercises the sub-quantum-remainder case the flush rule exists for.
fn honest_configs() -> Vec<ModelConfig> {
    vec![
        cfg(1, 2, 6, 1, Mutation::None),
        cfg(1, 4, 8, 2, Mutation::None),
        cfg(1, 5, 10, 5, Mutation::None),
        cfg(2, 2, 3, 1, Mutation::None),
        cfg(2, 3, 4, 2, Mutation::None),
        cfg(2, 4, 4, 2, Mutation::None),
    ]
}

#[test]
fn honest_protocol_is_exhaustively_clean() {
    let mut total = ModelStats { states: 0, transitions: 0 };
    for c in honest_configs() {
        let stats = check(&c).unwrap_or_else(|v| panic!("violation under {c:?}: {v}"));
        assert!(stats.states > 1, "trivial state space for {c:?}");
        total.states += stats.states;
        total.transitions += stats.transitions;
    }
    // the acceptance bar: a bounded run of meaningful size, checked
    // exhaustively (every transition's target state passed every
    // invariant)
    assert!(
        total.transitions >= 10_000,
        "bounded run too small to mean anything: {} transitions",
        total.transitions
    );
}

#[test]
fn skipping_the_credit_flush_deadlocks() {
    // window 5 / chunk 5: the receiver's quantized ack (quantum 2)
    // returns 4 credits and strands 1; without the
    // flush-before-blocking rule the sender waits forever for a full
    // chunk of credit. This is the exact bug class
    // `flush_all_credits()` in transport/socket.rs prevents.
    let err = check(&cfg(1, 5, 10, 5, Mutation::SkipCreditFlush))
        .expect_err("missing flush must deadlock");
    assert!(matches!(err, Violation::Deadlock { .. }), "wrong violation: {err}");
    // two-sender variant: the deadlock survives interleaving noise
    let err = check(&cfg(2, 5, 10, 5, Mutation::SkipCreditFlush))
        .expect_err("missing flush must deadlock with two streams too");
    assert!(matches!(err, Violation::Deadlock { .. }), "wrong violation: {err}");
}

#[test]
fn double_grant_breaks_conservation() {
    let err = check(&cfg(1, 2, 4, 1, Mutation::DoubleGrant)).expect_err("double grant must be caught");
    assert!(
        matches!(err, Violation::CreditLost { .. } | Violation::CreditOverflow { .. }),
        "wrong violation: {err}"
    );
}

#[test]
fn dropped_credit_breaks_conservation() {
    let err = check(&cfg(1, 2, 4, 1, Mutation::DropCredit)).expect_err("credit leak must be caught");
    assert!(matches!(err, Violation::CreditLost { .. }), "wrong violation: {err}");
}

#[test]
fn reordered_delivery_breaks_fifo() {
    // window 4 / chunk 2 lets two chunks be in flight at once, so the
    // mutated network can deliver the newer one first
    let err = check(&cfg(1, 4, 6, 2, Mutation::ReorderData)).expect_err("reorder must be caught");
    assert!(matches!(err, Violation::OutOfOrder { .. }), "wrong violation: {err}");
}

#[test]
fn checker_is_deterministic() {
    for c in honest_configs() {
        let a = check(&c).expect("run a");
        let b = check(&c).expect("run b");
        assert_eq!(a, b, "nondeterministic stats for {c:?}");
    }
}
