//! Exhaustive bounded model check of the credit flow-control
//! protocol, plus mutation tests proving the checker actually detects
//! each violation class (a model checker that cannot fail its
//! invariants verifies nothing).
//!
//! The state/transition/depth/final counts asserted here are exact
//! graph properties of each bounded configuration — independent of
//! exploration order — so any change to the protocol model or the
//! framework that alters the reachable state space fails loudly.
//!
//! See `rust/src/analysis/credit.rs` for the protocol model,
//! `rust/src/analysis/model.rs` for the framework, and `docs/MODEL.md`
//! for what is proved and within which bounds.

use fish::analysis::{check_credit, CheckOptions, CreditConfig, CreditMutation, Violation};

fn cfg(n_senders: usize, window: u32, tuples: u32, chunk: u32, mutation: CreditMutation) -> CreditConfig {
    CreditConfig { n_senders, window, tuples_per_sender: tuples, chunk, mutation }
}

/// The bounded configurations the honest protocol must pass, with
/// their exact (states, transitions, depth, finals). Multi-sender
/// configs cover cross-stream interleavings; the deeper single-sender
/// runs cover long grant/flush chains; window==chunk exercises the
/// sub-quantum-remainder case the flush rule exists for.
const HONEST: &[(usize, u32, u32, u32, (u64, u64, u64, u64))] = &[
    (1, 2, 6, 1, (34, 48, 18, 3)),
    (1, 4, 8, 2, (22, 30, 12, 3)),
    (1, 5, 10, 5, (13, 14, 10, 5)),
    (2, 2, 3, 1, (256, 672, 18, 9)),
    (2, 3, 4, 2, (49, 84, 12, 4)),
    (2, 4, 4, 2, (100, 240, 12, 9)),
    (3, 2, 3, 1, (4096, 16128, 27, 27)),
    (3, 2, 4, 1, (10648, 43560, 36, 27)),
];

#[test]
fn honest_protocol_is_exhaustively_clean_with_pinned_state_spaces() {
    let opts = CheckOptions::default();
    let mut total_transitions = 0u64;
    for &(n, w, t, c, (states, transitions, depth, finals)) in HONEST {
        let config = cfg(n, w, t, c, CreditMutation::None);
        let stats = check_credit(&config, &opts)
            .unwrap_or_else(|cx| panic!("violation under {config:?}:\n{}", cx.render()));
        assert_eq!(
            (stats.states, stats.transitions, stats.depth, stats.finals),
            (states, transitions, depth, finals),
            "state space changed for {config:?}"
        );
        total_transitions += stats.transitions;
    }
    // the acceptance bar: a bounded run of meaningful size, checked
    // exhaustively (every reached state passed every invariant)
    assert!(
        total_transitions >= 60_000,
        "bounded run too small to mean anything: {total_transitions} transitions"
    );
}

#[test]
fn honest_protocol_terminates() {
    // second traversal proves the transition graph acyclic on the
    // small configs — every run reaches quiescence
    let opts = CheckOptions { check_termination: true, ..CheckOptions::default() };
    for &(n, w, t, c) in &[(1, 2, 6, 1), (2, 3, 4, 2)] {
        check_credit(&cfg(n, w, t, c, CreditMutation::None), &opts)
            .unwrap_or_else(|cx| panic!("termination check failed:\n{}", cx.render()));
    }
}

#[test]
fn skipping_the_credit_flush_deadlocks() {
    // window 5 / chunk 5: the receiver's quantized ack (quantum 2)
    // returns 4 credits and strands 1; without the
    // flush-before-blocking rule the sender waits forever for a full
    // chunk of credit. This is the exact bug class
    // `flush_all_credits()` in transport/socket.rs prevents.
    let opts = CheckOptions::default();
    let cx = check_credit(&cfg(1, 5, 10, 5, CreditMutation::SkipCreditFlush), &opts)
        .expect_err("missing flush must deadlock");
    assert!(matches!(cx.violation, Violation::Deadlock), "wrong violation: {}", cx.violation);
    assert_eq!(cx.trace.len(), 3, "shortest deadlock trace changed:\n{}", cx.render());
    // two-sender variant: the deadlock survives interleaving noise
    let cx = check_credit(&cfg(2, 5, 10, 5, CreditMutation::SkipCreditFlush), &opts)
        .expect_err("missing flush must deadlock with two streams too");
    assert!(matches!(cx.violation, Violation::Deadlock), "wrong violation: {}", cx.violation);
}

#[test]
fn double_grant_breaks_conservation() {
    let cx = check_credit(&cfg(1, 4, 8, 2, CreditMutation::DoubleGrant), &CheckOptions::default())
        .expect_err("double grant must be caught");
    match &cx.violation {
        Violation::Property(p) => {
            assert!(
                p.property == "credit-conservation" || p.property == "credit-overflow",
                "wrong property: {p:?}"
            );
        }
        other => panic!("wrong violation: {other}"),
    }
    assert_eq!(cx.trace.len(), 2, "shortest counterexample changed:\n{}", cx.render());
}

#[test]
fn dropped_credit_breaks_conservation() {
    let cx = check_credit(&cfg(1, 4, 8, 2, CreditMutation::DropCredit), &CheckOptions::default())
        .expect_err("credit leak must be caught");
    match &cx.violation {
        Violation::Property(p) => assert_eq!(p.property, "credit-conservation", "{p:?}"),
        other => panic!("wrong violation: {other}"),
    }
    assert_eq!(cx.trace.len(), 2, "shortest counterexample changed:\n{}", cx.render());
}

#[test]
fn reordered_delivery_breaks_fifo() {
    // window 4 / chunk 2 lets two chunks be in flight at once, so the
    // mutated network can deliver the newer one first
    let cx = check_credit(&cfg(1, 4, 8, 2, CreditMutation::ReorderData), &CheckOptions::default())
        .expect_err("reorder must be caught");
    match &cx.violation {
        Violation::Property(p) => assert_eq!(p.property, "fifo-delivery", "{p:?}"),
        other => panic!("wrong violation: {other}"),
    }
    // shortest path: fill the 2-chunk pipeline, then the poisoned
    // delivery surfaces on the very next receive
    assert_eq!(cx.trace, vec!["send 0", "send 0", "deliver 0"], "trace changed:\n{}", cx.render());
}

#[test]
fn state_space_guard_reports_exceeded() {
    let opts = CheckOptions { max_states: 10, ..CheckOptions::default() };
    let cx = check_credit(&cfg(2, 2, 3, 1, CreditMutation::None), &opts)
        .expect_err("256-state config cannot fit in 10");
    assert!(
        matches!(cx.violation, Violation::StateSpaceExceeded { explored: 11 }),
        "wrong violation: {}",
        cx.violation
    );
}

#[test]
fn checker_is_deterministic() {
    let opts = CheckOptions::default();
    for &(n, w, t, c, _) in HONEST {
        let config = cfg(n, w, t, c, CreditMutation::None);
        let a = check_credit(&config, &opts).expect("run a");
        let b = check_credit(&config, &opts).expect("run b");
        assert_eq!(a, b, "nondeterministic stats for {config:?}");
    }
    // counterexamples are byte-stable too
    let config = cfg(1, 4, 8, 2, CreditMutation::DropCredit);
    let a = check_credit(&config, &opts).expect_err("a");
    let b = check_credit(&config, &opts).expect_err("b");
    assert_eq!(a.render(), b.render(), "nondeterministic counterexample");
}
