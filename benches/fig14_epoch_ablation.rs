//! Paper Fig. 14 — effectiveness of epoch-based hot-key identification.
//!
//! FISH with the epoch identifier (Alg. 1: intra-epoch counting +
//! inter-epoch decay) vs FISH with lifetime counting ("w/o epoch" — the
//! D-C/W-C identification style).
//!
//! Paper shape: the gap grows with workers and skew (up to 11.91x)
//! because lifetime counting misses recently-hot keys on time-evolving
//! streams.

#[path = "support/mod.rs"]
mod support;

use fish::coordinator::fish::EpochIdentifier;
use fish::coordinator::{Fish, Grouper, SchemeKind};
use fish::engine::{sim::Simulator, Topology};
use fish::report::{ratio, Table};
use support::*;

fn run_fish(cfg: &fish::config::Config, lifetime: bool) -> fish::engine::SimResult {
    let topology = Topology::from_config(cfg);
    let sources: Vec<Box<dyn Grouper>> = (0..cfg.sources)
        .map(|s| -> Box<dyn Grouper> {
            if lifetime {
                let id = Box::new(EpochIdentifier::lifetime(cfg.key_capacity));
                let workers: Vec<usize> = (0..cfg.workers).collect();
                Box::new(Fish::new(
                    id,
                    cfg.theta(),
                    cfg.d_min,
                    cfg.interval,
                    cfg.vnodes,
                    &workers,
                ))
            } else {
                fish::coordinator::make_kind(SchemeKind::Fish, cfg, s)
            }
        })
        .collect();
    let mut sim = Simulator::new(topology, sources, cfg.interarrival_ns);
    let mut gen = fish::workload::by_name(&cfg.workload, cfg.tuples, cfg.zipf_z, cfg.seed);
    sim.run(gen.as_mut())
}

fn main() {
    println!("=== Paper Fig. 14: epoch-based identification ablation ===\n");
    let mut t = Table::new(
        "Fig. 14 — execution time vs SG, with/without epochs",
        &["workers", "z", "w/ epoch", "w/o epoch", "w/o / w/"],
    );
    for &w in &WORKER_SCALES {
        for &z in &z_values() {
            let cfg = base_config("zf", w, z);
            let sg = run_scheme(cfg.clone(), SchemeKind::Shuffle);
            let with_e = run_fish(&cfg, false);
            let without = run_fish(&cfg, true);
            t.row(&[
                w.to_string(),
                format!("{z:.1}"),
                ratio(with_e.makespan as f64 / sg.makespan.max(1) as f64),
                ratio(without.makespan as f64 / sg.makespan.max(1) as f64),
                ratio(without.makespan as f64 / with_e.makespan.max(1) as f64),
            ]);
        }
    }
    finish(&t, "fig14_epoch");
}
