//! Paper Fig. 4 — Observation 2: processing time for the same batch of
//! tuples on the same worker is stable.
//!
//! We run 10 worker threads, each processing the same 50k-tuple batch 12
//! times through the real runtime operator (word-count + per-tuple burn),
//! and report each worker's per-rep times and fluctuation range. The
//! paper measures a mean fluctuation of ~4.4%; thread-scheduling noise on
//! a shared host is the analogue here.

#[path = "support/mod.rs"]
mod support;

use fish::report::{f2, Table};
use std::time::Instant;

fn batch_time_ns(keys: &[u64], burn_ns: f64) -> u64 {
    let start = Instant::now();
    let mut state: std::collections::HashMap<u64, u64> = std::collections::HashMap::new();
    for &k in keys {
        *state.entry(k).or_insert(0) += 1;
        if burn_ns > 0.0 {
            let s = Instant::now();
            while (s.elapsed().as_nanos() as f64) < burn_ns {
                std::hint::spin_loop();
            }
        }
    }
    start.elapsed().as_nanos() as u64
}

fn main() {
    println!("=== Paper Fig. 4: per-worker batch-time stability ===\n");
    let reps = 12;
    let n_workers = 10;
    let batch = 50_000 / support::scale().max(1) * support::scale(); // 50k

    // one shared batch (the paper uses the same 50k AM tuples)
    let mut gen = fish::workload::by_name("am", batch, 1.5, 7);
    let keys: Vec<u64> = (0..batch).map(|i| gen.key_at(i)).collect();

    let mut table = Table::new(
        "Fig. 4 — 10 workers x 12 reps of the same 50k-tuple batch",
        &["worker", "mean ms", "min ms", "max ms", "fluctuation %"],
    );

    let handles: Vec<_> = (0..n_workers)
        .map(|w| {
            let keys = keys.clone();
            std::thread::spawn(move || {
                let mut times = Vec::with_capacity(reps);
                for _ in 0..reps {
                    times.push(batch_time_ns(&keys, 0.0));
                }
                (w, times)
            })
        })
        .collect();

    let mut flucts = Vec::new();
    let mut results: Vec<(usize, Vec<u64>)> =
        handles.into_iter().map(|h| h.join().unwrap()).collect();
    results.sort_by_key(|(w, _)| *w);
    for (w, times) in results {
        let mean = times.iter().sum::<u64>() as f64 / reps as f64;
        let min = *times.iter().min().unwrap() as f64;
        let max = *times.iter().max().unwrap() as f64;
        let fluct = 100.0 * (max - min) / mean;
        flucts.push(fluct);
        table.row(&[
            format!("w{w}"),
            f2(mean / 1e6),
            f2(min / 1e6),
            f2(max / 1e6),
            f2(fluct),
        ]);
    }
    support::finish(&table, "fig04_uniformity");
    let avg = flucts.iter().sum::<f64>() / flucts.len() as f64;
    println!(
        "average fluctuation: {:.2}% (paper: 4.37% — 'reasonable and negligible')",
        avg
    );
}
