//! Paper Fig. 13 — choosing the hot-key threshold θ.
//!
//! θ ∈ {2/n, 1/2n, 1/4n, 1/8n} (expressed via the numerator: 2, 0.5,
//! 0.25, 0.125) across skew and worker counts.
//!
//! Paper shape: only θ = 2/n shows significant load imbalance; smaller
//! thresholds are equivalent on latency while 1/8n costs extra memory at
//! large n and low skew → the paper picks 1/4n.

#[path = "support/mod.rs"]
mod support;

use fish::coordinator::SchemeKind;
use fish::report::{ratio, Table};
use support::*;

fn main() {
    println!("=== Paper Fig. 13: hot-key threshold sweep ===\n");
    let thetas: [(f64, &str); 4] =
        [(2.0, "2/n"), (0.5, "1/2n"), (0.25, "1/4n"), (0.125, "1/8n")];
    let mut t = Table::new(
        "Fig. 13 — execution (vs SG) and memory (vs FG) per theta",
        &["workers", "z", "theta", "exec vs SG", "mem vs FG"],
    );
    for &w in &[16usize, 128] {
        for &z in &z_values() {
            let sg = run_scheme(base_config("zf", w, z), SchemeKind::Shuffle);
            for &(num, label) in &thetas {
                let mut cfg = base_config("zf", w, z);
                cfg.theta_num = num;
                let r = run_scheme(cfg, SchemeKind::Fish);
                t.row(&[
                    w.to_string(),
                    format!("{z:.1}"),
                    label.into(),
                    ratio(r.makespan as f64 / sg.makespan.max(1) as f64),
                    ratio(r.memory_normalized),
                ]);
            }
        }
    }
    finish(&t, "fig13_theta");
}
