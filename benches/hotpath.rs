//! Hot-path micro-benchmarks (the §Perf workhorse, not a paper figure).
//!
//! * per-tuple `route()` vs batched `route_batch()` ns/op for every
//!   grouping scheme, at batch sizes 256 and 1024 — tracks the
//!   batch-first API's amortisation win over the per-tuple path.
//! * aggregation-path ns/op: `PartialAgg::observe` (stage-one fold),
//!   `MergeStage` absorb (per merged entry), the shard-routing
//!   dispatch (`ShardRouter::shard_of`), and the windowed path
//!   (`WindowedPartial::observe` pane assignment, `WindowedMerge`
//!   absorb + watermark retirement per entry), and the transport wire
//!   codec (`encode_data` / `decode_frame` per tuple at engine batch
//!   size) — gated in CI as *ratios* against the observe cost, so the
//!   two-stage path and the serialize/deserialize hot loop can't
//!   silently regress relative to their own stage one.
//! * obs overhead: the same fold with `obs::span!` hooks disabled
//!   (`span_off` — must stay at ~parity with plain observe, the
//!   zero-cost-when-disabled gate), enabled (`span_on` — the real
//!   record-path price of `--trace-out`), and the merge loop with
//!   disabled per-flush hooks (`absorb_span_off`).
//! * identifier throughput: native Alg. 1 vs the XLA count-min path
//!   (AOT Pallas kernel via PJRT), amortised per tuple.
//!
//! Methodology: warm up, then N timed iterations over a pre-generated
//! key stream; report ns/op and the batched/per-tuple speedup. Used to
//! drive the EXPERIMENTS.md §Perf before/after log.
//!
//! Besides the console table/CSV, this bench emits
//! `bench_out/BENCH_hotpath.json` — machine-readable ns/op per scheme
//! plus run metadata — which CI's `perf-smoke` job uploads as an
//! artifact and gates against `benches/baselines/hotpath_smoke.json`
//! (batched-routing speedup must not regress >25%; the *ratio* is
//! compared, not raw ns/op, so the gate is robust to runner hardware).

#[path = "support/mod.rs"]
mod support;

use fish::aggregate::{Count, MergeStage, PartialAgg, ShardRouter, WindowedMerge, WindowedPartial};
use fish::config::Config;
use fish::coordinator::fish::{EpochIdentifier, Identifier};
use fish::coordinator::{make_kind, ClusterView, SchemeKind};
use fish::obs::{ClockDomain, TraceBuf};
use fish::report::{f2, Table};
use std::time::Instant;

fn bench_route(kind: SchemeKind, workers: usize, keys: &[u64]) -> f64 {
    let mut cfg = Config::default();
    cfg.workers = workers;
    let mut g = make_kind(kind, &cfg, 0);
    let worker_ids: Vec<usize> = (0..workers).collect();
    let times = vec![1_000.0; workers];
    // warmup
    for (i, &k) in keys.iter().take(keys.len() / 10).enumerate() {
        let view = ClusterView {
            now: i as u64,
            workers: &worker_ids,
            per_tuple_time: &times,
            n_slots: workers,
        };
        std::hint::black_box(g.route(k, &view));
    }
    let start = Instant::now();
    for (i, &k) in keys.iter().enumerate() {
        let view = ClusterView {
            now: i as u64 * 100,
            workers: &worker_ids,
            per_tuple_time: &times,
            n_slots: workers,
        };
        std::hint::black_box(g.route(k, &view));
    }
    start.elapsed().as_nanos() as f64 / keys.len() as f64
}

fn bench_route_batch(kind: SchemeKind, workers: usize, keys: &[u64], batch: usize) -> f64 {
    let mut cfg = Config::default();
    cfg.workers = workers;
    let mut g = make_kind(kind, &cfg, 0);
    let worker_ids: Vec<usize> = (0..workers).collect();
    let times = vec![1_000.0; workers];
    let mut out = vec![0usize; batch];
    // warmup (same 10% prefix as the per-tuple bench)
    for (bi, chunk) in keys[..keys.len() / 10].chunks(batch).enumerate() {
        let view = ClusterView {
            now: (bi * batch) as u64,
            workers: &worker_ids,
            per_tuple_time: &times,
            n_slots: workers,
        };
        g.route_batch(chunk, &mut out[..chunk.len()], &view);
        std::hint::black_box(&out);
    }
    let start = Instant::now();
    for (bi, chunk) in keys.chunks(batch).enumerate() {
        let view = ClusterView {
            now: (bi * batch) as u64 * 100,
            workers: &worker_ids,
            per_tuple_time: &times,
            n_slots: workers,
        };
        g.route_batch(chunk, &mut out[..chunk.len()], &view);
        std::hint::black_box(&out);
    }
    start.elapsed().as_nanos() as f64 / keys.len() as f64
}

/// Stage-one fold cost: `PartialAgg::observe` ns/op over the key stream.
fn bench_partial_observe(keys: &[u64]) -> f64 {
    let mut p = PartialAgg::new(Count);
    for &k in keys.iter().take(keys.len() / 10) {
        p.observe(k, 1);
    }
    let start = Instant::now();
    for &k in keys {
        p.observe(k, 1);
    }
    let ns = start.elapsed().as_nanos() as f64 / keys.len() as f64;
    std::hint::black_box(p.len());
    ns
}

/// Stage-two merge cost: `MergeStage::absorb` ns per merged entry, over
/// realistic flush batches (a partial drained every `flush_every` keys).
fn bench_merge_absorb(keys: &[u64], flush_every: usize) -> f64 {
    let mut batches = Vec::new();
    let mut p = PartialAgg::new(Count);
    for (i, &k) in keys.iter().enumerate() {
        p.observe(k, 1);
        if (i + 1) % flush_every == 0 {
            batches.push(p.flush());
        }
    }
    if !p.is_empty() {
        batches.push(p.flush());
    }
    let entries: usize = batches.iter().map(|b| b.len()).sum();
    let mut m = MergeStage::new(Count);
    let start = Instant::now();
    for b in batches {
        m.absorb(b);
    }
    let ns = start.elapsed().as_nanos() as f64 / entries.max(1) as f64;
    std::hint::black_box(m.len());
    ns
}

/// Shard-routing dispatch cost: `ShardRouter::shard_of` ns/op on an
/// `n_shards`-way fabric (the per-entry price of scattering a flush).
fn bench_shard_route(keys: &[u64], n_shards: usize) -> f64 {
    let router = ShardRouter::new(n_shards);
    for &k in keys.iter().take(keys.len() / 10) {
        std::hint::black_box(router.shard_of(k));
    }
    let start = Instant::now();
    for &k in keys {
        std::hint::black_box(router.shard_of(k));
    }
    start.elapsed().as_nanos() as f64 / keys.len() as f64
}

/// Windowed stage-one fold cost: `WindowedPartial::observe` ns/op with
/// event time advancing through panes — the pane-assignment price on
/// top of the plain `PartialAgg::observe` fold.
fn bench_window_observe(keys: &[u64]) -> f64 {
    // ~64 tuples per pane: pane advances are frequent enough to price
    let window_ns = 6_400;
    let warm = keys.len() / 10;
    let mut p = WindowedPartial::new(Count, window_ns);
    for (i, &k) in keys.iter().take(warm).enumerate() {
        p.observe(k, 1, i as u64 * 100);
    }
    p.flush();
    let start = Instant::now();
    for (i, &k) in keys.iter().enumerate() {
        // event time continues past the warmup: every measured observe
        // takes the hot-pane path being priced, not the laggard
        // side-table path a timestamp rewind would hit
        p.observe(k, 1, (warm + i) as u64 * 100);
    }
    let ns = start.elapsed().as_nanos() as f64 / keys.len() as f64;
    std::hint::black_box(p.len());
    ns
}

/// Windowed stage-two cost: `WindowedMerge` absorb + watermark
/// retirement, ns per merged entry over realistic per-pane flush
/// batches (a windowed partial drained every `flush_every` keys, panes
/// retired as the watermark passes them).
fn bench_window_retire(keys: &[u64], flush_every: usize) -> f64 {
    let window_ns = 6_400;
    let mut batches = Vec::new();
    let mut p = WindowedPartial::new(Count, window_ns);
    for (i, &k) in keys.iter().enumerate() {
        p.observe(k, 1, i as u64 * 100);
        if (i + 1) % flush_every == 0 {
            batches.push((i as u64 * 100, p.flush()));
        }
    }
    if !p.is_empty() {
        batches.push((keys.len() as u64 * 100, p.flush()));
    }
    let entries: usize =
        batches.iter().map(|(_, panes)| panes.iter().map(|(_, b)| b.len()).sum::<usize>()).sum();
    let mut m = WindowedMerge::new(Count, window_ns, 1024);
    let start = Instant::now();
    for (watermark, panes) in batches {
        for (win, sub) in panes {
            m.absorb(win, sub);
        }
        m.advance(watermark);
    }
    let ns = start.elapsed().as_nanos() as f64 / entries.max(1) as f64;
    std::hint::black_box(m.finish().windows.len());
    ns
}

/// Wire serialize cost: `encode_data` ns per tuple over engine-sized
/// batches — the per-tuple price a socket lane adds on the way out.
fn bench_wire_encode(keys: &[u64], batch: usize) -> f64 {
    use fish::transport::wire::{self, Msg};
    let msgs: Vec<Msg> = keys
        .iter()
        .enumerate()
        .map(|(i, &k)| Msg { key: k, emit_ns: i as u64 * 100, ts: i as u64 * 100 })
        .collect();
    let mut buf = Vec::new();
    for chunk in msgs[..msgs.len() / 10].chunks(batch) {
        buf.clear();
        wire::encode_data(chunk, &mut buf);
        std::hint::black_box(&buf);
    }
    let start = Instant::now();
    for chunk in msgs.chunks(batch) {
        buf.clear();
        wire::encode_data(chunk, &mut buf);
        std::hint::black_box(&buf);
    }
    start.elapsed().as_nanos() as f64 / msgs.len() as f64
}

/// Wire deserialize cost: `decode_frame` ns per tuple over the frames
/// [`bench_wire_encode`] ships — the inbound price on a socket lane.
fn bench_wire_decode(keys: &[u64], batch: usize) -> f64 {
    use fish::transport::wire::{self, Msg};
    let msgs: Vec<Msg> = keys
        .iter()
        .enumerate()
        .map(|(i, &k)| Msg { key: k, emit_ns: i as u64 * 100, ts: i as u64 * 100 })
        .collect();
    let frames: Vec<Vec<u8>> = msgs
        .chunks(batch)
        .map(|chunk| {
            let mut buf = Vec::new();
            wire::encode_data(chunk, &mut buf);
            buf
        })
        .collect();
    for frame in frames.iter().take(frames.len() / 10) {
        std::hint::black_box(wire::decode_frame(frame).unwrap());
    }
    let start = Instant::now();
    for frame in &frames {
        std::hint::black_box(wire::decode_frame(frame).unwrap());
    }
    start.elapsed().as_nanos() as f64 / msgs.len() as f64
}

/// Disabled-instrumentation cost: the stage-one fold with an
/// `obs::span!` per op against a disabled [`TraceBuf`] — prices the
/// one `is_active()` branch the tracing hooks leave in hot loops when
/// no `--trace-out` is armed. The buffer reference goes through
/// `black_box` so the branch reads memory like the engine's does
/// instead of constant-folding away. Gated vs plain observe: this
/// ratio rising past ~parity means the zero-cost-when-disabled
/// contract broke.
fn bench_span_off(keys: &[u64]) -> f64 {
    let mut p = PartialAgg::new(Count);
    let mut buf = TraceBuf::disabled();
    let obs = std::hint::black_box(&mut buf);
    for (i, &k) in keys.iter().take(keys.len() / 10).enumerate() {
        p.observe(k, 1);
        fish::obs::span!(obs, "fold", i as u64, i as u64 + 1);
    }
    let start = Instant::now();
    for (i, &k) in keys.iter().enumerate() {
        p.observe(k, 1);
        fish::obs::span!(obs, "fold", i as u64, i as u64 + 1);
    }
    let ns = start.elapsed().as_nanos() as f64 / keys.len() as f64;
    std::hint::black_box((p.len(), buf.dropped()));
    ns
}

/// Enabled-instrumentation cost: the same fold against an *active*
/// buffer with capacity for the whole stream, so every op pays the
/// real record path (branch + `Event` push), not the ring-full drop
/// path. Informational ceiling for what `--trace-out` costs a hot
/// loop; gated loosely since it is expected to be several observes.
fn bench_span_on(keys: &[u64]) -> f64 {
    let mut p = PartialAgg::new(Count);
    let mut buf = TraceBuf::with_cap(0, 0, ClockDomain::Virtual, keys.len() * 2);
    let obs = std::hint::black_box(&mut buf);
    for (i, &k) in keys.iter().take(keys.len() / 10).enumerate() {
        p.observe(k, 1);
        fish::obs::span!(obs, "fold", i as u64, i as u64 + 1);
    }
    let start = Instant::now();
    for (i, &k) in keys.iter().enumerate() {
        p.observe(k, 1);
        fish::obs::span!(obs, "fold", i as u64, i as u64 + 1);
    }
    let ns = start.elapsed().as_nanos() as f64 / keys.len() as f64;
    std::hint::black_box((p.len(), buf.events().len()));
    ns
}

/// Disabled-instrumentation cost on the merge path: the
/// [`bench_merge_absorb`] loop with the shard loop's per-flush span +
/// counter hooks compiled in but disabled, amortised per merged entry.
/// Gated against the plain `merge_absorb` ratio: per-batch hooks must
/// stay invisible at flush granularity when tracing is off.
fn bench_absorb_span_off(keys: &[u64], flush_every: usize) -> f64 {
    let mut batches = Vec::new();
    let mut p = PartialAgg::new(Count);
    for (i, &k) in keys.iter().enumerate() {
        p.observe(k, 1);
        if (i + 1) % flush_every == 0 {
            batches.push(p.flush());
        }
    }
    if !p.is_empty() {
        batches.push(p.flush());
    }
    let entries: usize = batches.iter().map(|b| b.len()).sum();
    let mut m = MergeStage::new(Count);
    let mut buf = TraceBuf::disabled();
    let obs = std::hint::black_box(&mut buf);
    let start = Instant::now();
    for (seq, b) in batches.into_iter().enumerate() {
        let t0 = seq as u64 * 1_000;
        let n = b.len() as u64;
        m.absorb(b);
        fish::obs::span!(obs, "merge_absorb", t0, t0 + 1, seq = seq as u64);
        fish::obs::count!(obs, "absorb_entries", t0 + 1, n);
    }
    let ns = start.elapsed().as_nanos() as f64 / entries.max(1) as f64;
    std::hint::black_box((m.len(), buf.dropped()));
    ns
}

fn bench_identifier_native(keys: &[u64], epoch: usize, cap: usize) -> f64 {
    let mut id = EpochIdentifier::new(cap, epoch, 0.2);
    let start = Instant::now();
    for &k in keys {
        id.observe(k);
        std::hint::black_box(id.estimate(k));
    }
    start.elapsed().as_nanos() as f64 / keys.len() as f64
}

fn bench_identifier_xla(keys: &[u64], cap: usize) -> Option<f64> {
    let mut id = fish::runtime::XlaIdentifier::new("artifacts", cap, 1024, 0.2).ok()?;
    // warmup: one epoch to compile-hot the path
    for &k in keys.iter().take(id.epoch_len()) {
        id.observe(k);
    }
    let start = Instant::now();
    for &k in keys {
        id.observe(k);
        std::hint::black_box(id.estimate(k));
    }
    Some(start.elapsed().as_nanos() as f64 / keys.len() as f64)
}

fn main() {
    println!("=== hot-path micro-benchmarks ===\n");
    let opts = support::BenchOpts::from_env();
    let n = opts.tuples(400_000);
    let mut gen = fish::workload::by_name("zf", n, 1.5, opts.seed);
    let keys: Vec<u64> = (0..n).map(|i| gen.key_at(i)).collect();

    let mut t = Table::new(
        "routing cost per scheme: per-tuple route() vs route_batch()",
        &["scheme", "workers", "tuple ns", "b256 ns", "b1024 ns", "speedup@1024"],
    );
    let mut json_rows: Vec<String> = Vec::new();
    for kind in SchemeKind::all() {
        for &w in &[16usize, 128] {
            let tuple_ns = bench_route(kind, w, &keys);
            let b256 = bench_route_batch(kind, w, &keys, 256);
            let b1024 = bench_route_batch(kind, w, &keys, 1024);
            let speedup = tuple_ns / b1024.max(1e-9);
            t.row(&[
                kind.name().into(),
                w.to_string(),
                f2(tuple_ns),
                f2(b256),
                f2(b1024),
                format!("{speedup:.2}x"),
            ]);
            json_rows.push(format!(
                "    {{\"scheme\": \"{}\", \"workers\": {w}, \"tuple_ns\": {tuple_ns:.3}, \
                 \"b256_ns\": {b256:.3}, \"b1024_ns\": {b1024:.3}, \
                 \"speedup_b1024\": {speedup:.4}}}",
                kind.name()
            ));
        }
    }
    support::finish_with(&opts, &t, "hotpath_route");

    // aggregation path: stage-one observe, stage-two absorb, and the
    // shard-routing dispatch the merge fabric adds. CI gates the
    // *ratios* vs observe (same machine, same run), not raw ns/op.
    let partial_ns = bench_partial_observe(&keys);
    let absorb_ns = bench_merge_absorb(&keys, 4096);
    let shard_ns = bench_shard_route(&keys, 8);
    let window_observe_ns = bench_window_observe(&keys);
    let window_retire_ns = bench_window_retire(&keys, 4096);
    let wire_encode_ns = bench_wire_encode(&keys, 1024);
    let wire_decode_ns = bench_wire_decode(&keys, 1024);
    let span_off_ns = bench_span_off(&keys);
    let span_on_ns = bench_span_on(&keys);
    let absorb_span_off_ns = bench_absorb_span_off(&keys, 4096);
    let mut ta = Table::new(
        "aggregation path: two-stage fold + shard dispatch + window panes + wire codec + obs hooks",
        &["op", "ns/op", "ratio vs observe"],
    );
    let mut agg_json_rows: Vec<String> = Vec::new();
    for (op, ns_op) in [
        ("partial_observe", partial_ns),
        ("merge_absorb", absorb_ns),
        ("shard_route8", shard_ns),
        ("window_observe", window_observe_ns),
        ("window_retire", window_retire_ns),
        ("wire_encode", wire_encode_ns),
        ("wire_decode", wire_decode_ns),
        ("span_off", span_off_ns),
        ("span_on", span_on_ns),
        ("absorb_span_off", absorb_span_off_ns),
    ] {
        let ratio = ns_op / partial_ns.max(1e-9);
        ta.row(&[op.into(), f2(ns_op), format!("{ratio:.2}x")]);
        agg_json_rows.push(format!(
            "    {{\"op\": \"{op}\", \"ns\": {ns_op:.3}, \"ratio_vs_observe\": {ratio:.4}}}"
        ));
    }
    support::finish_with(&opts, &ta, "hotpath_agg");

    // machine-readable sibling of the tables above (CI artifact + gate)
    let json = format!(
        "{{\n  \"meta\": {},\n  \"tuples\": {n},\n  \"results\": [\n{}\n  ],\n  \
         \"agg_results\": [\n{}\n  ]\n}}\n",
        opts.meta_json(),
        json_rows.join(",\n"),
        agg_json_rows.join(",\n")
    );
    match support::save_json(&opts, "BENCH_hotpath.json", &json) {
        Ok(path) => println!("[saved {}]\n", path.display()),
        Err(e) => eprintln!("[json save failed: {e}]\n"),
    }

    let mut t2 = Table::new(
        "identifier cost per tuple (observe + estimate)",
        &["backend", "ns/op", "Mops"],
    );
    let native = bench_identifier_native(&keys, 1000, 1000);
    t2.row(&["native (Alg. 1)".into(), f2(native), f2(1_000.0 / native)]);
    match bench_identifier_xla(&keys[..(100_000.min(keys.len()))], 1000) {
        Some(xla) => {
            t2.row(&["xla-cms (PJRT)".into(), f2(xla), f2(1_000.0 / xla)]);
        }
        None => println!("[xla-cms skipped: run `make artifacts` first]"),
    }
    support::finish_with(&opts, &t2, "hotpath_identifier");
}
