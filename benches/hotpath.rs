//! Hot-path micro-benchmarks (the §Perf workhorse, not a paper figure).
//!
//! * per-tuple `route()` vs batched `route_batch()` ns/op for every
//!   grouping scheme, at batch sizes 256 and 1024 — tracks the
//!   batch-first API's amortisation win over the per-tuple path.
//! * identifier throughput: native Alg. 1 vs the XLA count-min path
//!   (AOT Pallas kernel via PJRT), amortised per tuple.
//!
//! Methodology: warm up, then N timed iterations over a pre-generated
//! key stream; report ns/op and the batched/per-tuple speedup. Used to
//! drive the EXPERIMENTS.md §Perf before/after log.
//!
//! Besides the console table/CSV, this bench emits
//! `bench_out/BENCH_hotpath.json` — machine-readable ns/op per scheme
//! plus run metadata — which CI's `perf-smoke` job uploads as an
//! artifact and gates against `benches/baselines/hotpath_smoke.json`
//! (batched-routing speedup must not regress >25%; the *ratio* is
//! compared, not raw ns/op, so the gate is robust to runner hardware).

#[path = "support/mod.rs"]
mod support;

use fish::config::Config;
use fish::coordinator::fish::{EpochIdentifier, Identifier};
use fish::coordinator::{make_kind, ClusterView, SchemeKind};
use fish::report::{f2, Table};
use std::time::Instant;

fn bench_route(kind: SchemeKind, workers: usize, keys: &[u64]) -> f64 {
    let mut cfg = Config::default();
    cfg.workers = workers;
    let mut g = make_kind(kind, &cfg, 0);
    let worker_ids: Vec<usize> = (0..workers).collect();
    let times = vec![1_000.0; workers];
    // warmup
    for (i, &k) in keys.iter().take(keys.len() / 10).enumerate() {
        let view = ClusterView {
            now: i as u64,
            workers: &worker_ids,
            per_tuple_time: &times,
            n_slots: workers,
        };
        std::hint::black_box(g.route(k, &view));
    }
    let start = Instant::now();
    for (i, &k) in keys.iter().enumerate() {
        let view = ClusterView {
            now: i as u64 * 100,
            workers: &worker_ids,
            per_tuple_time: &times,
            n_slots: workers,
        };
        std::hint::black_box(g.route(k, &view));
    }
    start.elapsed().as_nanos() as f64 / keys.len() as f64
}

fn bench_route_batch(kind: SchemeKind, workers: usize, keys: &[u64], batch: usize) -> f64 {
    let mut cfg = Config::default();
    cfg.workers = workers;
    let mut g = make_kind(kind, &cfg, 0);
    let worker_ids: Vec<usize> = (0..workers).collect();
    let times = vec![1_000.0; workers];
    let mut out = vec![0usize; batch];
    // warmup (same 10% prefix as the per-tuple bench)
    for (bi, chunk) in keys[..keys.len() / 10].chunks(batch).enumerate() {
        let view = ClusterView {
            now: (bi * batch) as u64,
            workers: &worker_ids,
            per_tuple_time: &times,
            n_slots: workers,
        };
        g.route_batch(chunk, &mut out[..chunk.len()], &view);
        std::hint::black_box(&out);
    }
    let start = Instant::now();
    for (bi, chunk) in keys.chunks(batch).enumerate() {
        let view = ClusterView {
            now: (bi * batch) as u64 * 100,
            workers: &worker_ids,
            per_tuple_time: &times,
            n_slots: workers,
        };
        g.route_batch(chunk, &mut out[..chunk.len()], &view);
        std::hint::black_box(&out);
    }
    start.elapsed().as_nanos() as f64 / keys.len() as f64
}

fn bench_identifier_native(keys: &[u64], epoch: usize, cap: usize) -> f64 {
    let mut id = EpochIdentifier::new(cap, epoch, 0.2);
    let start = Instant::now();
    for &k in keys {
        id.observe(k);
        std::hint::black_box(id.estimate(k));
    }
    start.elapsed().as_nanos() as f64 / keys.len() as f64
}

fn bench_identifier_xla(keys: &[u64], cap: usize) -> Option<f64> {
    let mut id = fish::runtime::XlaIdentifier::new("artifacts", cap, 1024, 0.2).ok()?;
    // warmup: one epoch to compile-hot the path
    for &k in keys.iter().take(id.epoch_len()) {
        id.observe(k);
    }
    let start = Instant::now();
    for &k in keys {
        id.observe(k);
        std::hint::black_box(id.estimate(k));
    }
    Some(start.elapsed().as_nanos() as f64 / keys.len() as f64)
}

fn main() {
    println!("=== hot-path micro-benchmarks ===\n");
    let opts = support::BenchOpts::from_env();
    let n = opts.tuples(400_000);
    let mut gen = fish::workload::by_name("zf", n, 1.5, opts.seed);
    let keys: Vec<u64> = (0..n).map(|i| gen.key_at(i)).collect();

    let mut t = Table::new(
        "routing cost per scheme: per-tuple route() vs route_batch()",
        &["scheme", "workers", "tuple ns", "b256 ns", "b1024 ns", "speedup@1024"],
    );
    let mut json_rows: Vec<String> = Vec::new();
    for kind in SchemeKind::all() {
        for &w in &[16usize, 128] {
            let tuple_ns = bench_route(kind, w, &keys);
            let b256 = bench_route_batch(kind, w, &keys, 256);
            let b1024 = bench_route_batch(kind, w, &keys, 1024);
            let speedup = tuple_ns / b1024.max(1e-9);
            t.row(&[
                kind.name().into(),
                w.to_string(),
                f2(tuple_ns),
                f2(b256),
                f2(b1024),
                format!("{speedup:.2}x"),
            ]);
            json_rows.push(format!(
                "    {{\"scheme\": \"{}\", \"workers\": {w}, \"tuple_ns\": {tuple_ns:.3}, \
                 \"b256_ns\": {b256:.3}, \"b1024_ns\": {b1024:.3}, \
                 \"speedup_b1024\": {speedup:.4}}}",
                kind.name()
            ));
        }
    }
    support::finish_with(&opts, &t, "hotpath_route");

    // machine-readable sibling of the table above (CI artifact + gate)
    let json = format!(
        "{{\n  \"meta\": {},\n  \"tuples\": {n},\n  \"results\": [\n{}\n  ]\n}}\n",
        opts.meta_json(),
        json_rows.join(",\n")
    );
    match support::save_json(&opts, "BENCH_hotpath.json", &json) {
        Ok(path) => println!("[saved {}]\n", path.display()),
        Err(e) => eprintln!("[json save failed: {e}]\n"),
    }

    let mut t2 = Table::new(
        "identifier cost per tuple (observe + estimate)",
        &["backend", "ns/op", "Mops"],
    );
    let native = bench_identifier_native(&keys, 1000, 1000);
    t2.row(&["native (Alg. 1)".into(), f2(native), f2(1_000.0 / native)]);
    match bench_identifier_xla(&keys[..(100_000.min(keys.len()))], 1000) {
        Some(xla) => {
            t2.row(&["xla-cms (PJRT)".into(), f2(xla), f2(1_000.0 / xla)]);
        }
        None => println!("[xla-cms skipped: run `make artifacts` first]"),
    }
    support::finish_with(&opts, &t2, "hotpath_identifier");
}
