//! Paper Figs. 18 + 19 + 20 — the "practical deployment on Apache
//! Storm" experiments, on our threaded runtime engine (the Storm
//! stand-in): 128 workers, MT-like and AM-like workloads.
//!
//! * Fig. 18 — end-to-end latency (avg / p50 / p95 / p99) per scheme.
//! * Fig. 19 — throughput per scheme.
//! * Fig. 20 — FISH memory overhead relative to SG across skew.
//!
//! Paper shape: FG lowest throughput & worst tail; FISH ≈ SG on both
//! latency and throughput (paper: −87.12% avg / −76.34% p99 vs W-C,
//! 1.32x W-C throughput) at a few percent of SG's memory.

#[path = "support/mod.rs"]
mod support;

use fish::coordinator::SchemeKind;
use fish::engine::Pipeline;
use fish::report::{f2, ns, ratio, Table};
use std::sync::Arc;
use support::*;

fn main() {
    println!("=== Paper Figs. 18-20: practical deployment (threaded runtime) ===\n");
    // scaled: 8 sources, 64 workers (paper: 32 x 128; thread budget)
    let sources_n = 8;
    let workers = 64;
    let tuples = 150_000 * scale();

    let mut lat = Table::new(
        "Fig. 18 — end-to-end latency per scheme",
        &["workload", "scheme", "avg", "p50", "p95", "p99"],
    );
    let mut thr = Table::new(
        "Fig. 19 — throughput per scheme",
        &["workload", "scheme", "tuples/s", "vs SG"],
    );

    for workload in ["mt", "am"] {
        let mut cfg = base_config(workload, workers, 1.5);
        cfg.tuples = tuples;
        cfg.sources = sources_n;
        cfg.service_ns = 1_500;
        cfg.interval = 2_000_000; // 2ms HWA interval on the wall clock
        let mut gen = fish::workload::by_name(workload, tuples, 1.5, cfg.seed);
        let trace = Arc::new(fish::workload::materialise(gen.as_mut(), 0));
        let mut sg_thr = None;
        for kind in SchemeKind::all() {
            let r = Pipeline::builder()
                .config(cfg.clone())
                .scheme(kind)
                .interarrival_ns(0)
                .per_tuple_ns(vec![cfg.service_ns as f64])
                .trace(trace.clone())
                .build_rt()
                .run();
            let (mean, p50, p95, p99) = r.latency.summary();
            if kind == SchemeKind::Shuffle {
                sg_thr = Some(r.throughput);
            }
            lat.row(&[
                workload.into(),
                kind.name().into(),
                ns(mean as u64),
                ns(p50),
                ns(p95),
                ns(p99),
            ]);
            thr.row(&[
                workload.into(),
                kind.name().into(),
                format!("{:.0}", r.throughput),
                sg_thr
                    .map(|t| ratio(r.throughput / t))
                    .unwrap_or_else(|| "-".into()),
            ]);
        }
    }
    finish(&lat, "fig18_latency");
    finish(&thr, "fig19_throughput");

    // Fig. 20: FISH memory relative to SG across skew (runtime state)
    let mut mem = Table::new(
        "Fig. 20 — FISH memory overhead relative to SG (ZF)",
        &["z", "fish entries", "sg entries", "fish/sg %"],
    );
    for &z in &z_values() {
        let mut cfg = base_config("zf", workers, z);
        cfg.tuples = tuples;
        cfg.sources = sources_n;
        let mut gen = fish::workload::by_name("zf", tuples, z, cfg.seed);
        let trace = Arc::new(fish::workload::materialise(gen.as_mut(), 0));
        let run_kind = |kind: SchemeKind| {
            Pipeline::builder()
                .config(cfg.clone())
                .scheme(kind)
                .interarrival_ns(0)
                .per_tuple_ns(vec![500.0])
                .trace(trace.clone())
                .build_rt()
                .run()
        };
        let fish_r = run_kind(SchemeKind::Fish);
        let sg_r = run_kind(SchemeKind::Shuffle);
        mem.row(&[
            format!("{z:.1}"),
            fish_r.entries.to_string(),
            sg_r.entries.to_string(),
            f2(100.0 * fish_r.entries as f64 / sg_r.entries.max(1) as f64),
        ]);
    }
    finish(&mem, "fig20_memory_vs_sg");
}
