//! Paper Fig. 9 — execution time of PKG, D-C, W-C and FISH on the
//! real-world-like AM and MT workloads, normalised to SG, at
//! 16/32/64/128 workers.
//!
//! Paper shape: FISH ≈ SG (worst case 1.07x); PKG degrades steeply with
//! worker count (up to 8.32x on MT); D-C/W-C sit between and also
//! degrade with scale.

#[path = "support/mod.rs"]
mod support;

use fish::coordinator::SchemeKind;
use fish::report::{ratio, Table};
use support::*;

fn main() {
    println!("=== Paper Fig. 9: execution time vs SG (real-world-like) ===\n");
    for workload in ["am", "mt"] {
        let mut t = Table::new(
            &format!("Fig. 9 ({workload}) — execution time normalised to SG"),
            &["workers", "pkg", "dc", "wc", "fish"],
        );
        for &w in &WORKER_SCALES {
            let cfg = base_config(workload, w, 1.5);
            let mut cells = vec![w.to_string()];
            for kind in [
                SchemeKind::Pkg,
                SchemeKind::DChoices,
                SchemeKind::WChoices,
                SchemeKind::Fish,
            ] {
                let (_r, vs_sg) = run_vs_sg(&cfg, kind);
                cells.push(ratio(vs_sg));
            }
            t.row(&cells);
        }
        finish(&t, &format!("fig09_{workload}"));
    }
}
