//! Identifier ablation (paper §2.4 + §4.1): FISH's epoch-based
//! identification vs the two baseline families it replaces, plus the
//! XLA/Pallas CMS backend.
//!
//! Columns reproduce the paper's §4.1 argument quantitatively:
//! * decay ops — epoch-level decay does ~N_epoch× fewer multiplications
//!   than tuple-level time-aware counting ("three orders of magnitude").
//! * entries — sliding windows pay memory linear in the window.
//! * hot-hit % — fraction of tuples whose true recent-hot key (ground
//!   truth: exact 10k-tuple sliding window) the identifier also ranks
//!   hot. Accuracy must not be sacrificed for the efficiency.

#[path = "support/mod.rs"]
mod support;

use fish::coordinator::fish::{
    EpochIdentifier, Identifier, TupleDecayIdentifier, WindowIdentifier,
};
use fish::report::{f2, Table};
use fish::sketch::SlidingWindow;
use std::time::Instant;

struct Row {
    name: &'static str,
    ns_per_op: f64,
    entries: usize,
    decay_ops: u64,
    hot_hits: f64,
}

fn eval(mut id: Box<dyn Identifier>, keys: &[u64], theta_mass: f64, name: &'static str,
        decay_ops: impl Fn(&dyn Identifier) -> u64) -> Row {
    let mut truth = SlidingWindow::new(10_000);
    let mut hits = 0u64;
    let mut trials = 0u64;
    let start = Instant::now();
    for (i, &k) in keys.iter().enumerate() {
        id.observe(k);
        truth.observe(k);
        // sample accuracy every 100 tuples (outside the timed cost? —
        // the truth window dominates; keep symmetric across backends)
        if i % 100 == 99 {
            let true_hot = truth.count(k) as f64 > theta_mass * truth.len() as f64;
            if true_hot {
                trials += 1;
                let est_hot = id.estimate(k) > theta_mass * id.total();
                if est_hot {
                    hits += 1;
                }
            }
        }
    }
    let ns = start.elapsed().as_nanos() as f64 / keys.len() as f64;
    Row {
        name,
        ns_per_op: ns,
        entries: id.entries(),
        decay_ops: decay_ops(id.as_ref()),
        hot_hits: if trials > 0 { 100.0 * hits as f64 / trials as f64 } else { 100.0 },
    }
}

fn main() {
    println!("=== identifier ablation (paper §4.1) ===\n");
    let n = 300_000 * support::scale();
    let mut gen = fish::workload::by_name("zf", n, 1.5, 9);
    let keys: Vec<u64> = (0..n).map(|i| gen.key_at(i)).collect();
    let theta = 0.01; // hotness = >1% of recent mass

    let mut rows = Vec::new();
    rows.push(eval(
        Box::new(EpochIdentifier::new(1_000, 1_000, 0.2)),
        &keys,
        theta,
        "epoch (FISH Alg.1)",
        |id| (id.epochs()) * 1_000, // ≤ K_max multiplications per epoch
    ));
    rows.push(eval(
        Box::new(TupleDecayIdentifier::new(1_000, 0.2, 1_000)),
        &keys,
        theta,
        "tuple-decay [16-18]",
        |_| 0,
    ));
    // decay_ops for tuple-decay needs the concrete type; recompute:
    {
        let mut td = TupleDecayIdentifier::new(1_000, 0.2, 1_000);
        for &k in &keys {
            td.observe(k);
        }
        rows[1].decay_ops = td.decay_ops;
    }
    rows.push(eval(
        Box::new(WindowIdentifier::new(10_000)),
        &keys,
        theta,
        "sliding-window [19-23]",
        |_| 0,
    ));
    if std::path::Path::new("artifacts/manifest.txt").exists() {
        rows.push(eval(
            Box::new(fish::runtime::XlaIdentifier::new("artifacts", 1_000, 1_024, 0.2).unwrap()),
            &keys[..100_000.min(keys.len())],
            theta,
            "xla-cms (Pallas/PJRT)",
            |id| id.epochs() * 8_192, // D×W decay inside the kernel
        ));
    }

    let mut t = Table::new(
        "recent-hot-key identification backends",
        &["backend", "ns/op", "entries", "decay ops", "hot-hit %"],
    );
    for r in &rows {
        t.row(&[
            r.name.into(),
            f2(r.ns_per_op),
            r.entries.to_string(),
            r.decay_ops.to_string(),
            f2(r.hot_hits),
        ]);
    }
    support::finish(&t, "identifiers");
    println!(
        "paper claim check: tuple-decay performs ~{}x the decay work of epoch-level decay",
        if rows[0].decay_ops > 0 { rows[1].decay_ops / rows[0].decay_ops.max(1) } else { 0 }
    );
}
