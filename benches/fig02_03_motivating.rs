//! Paper Figs. 2 + 3 — the motivating study.
//!
//! Latency (Fig. 2) and normalised memory overhead (Fig. 3) of FG, PKG,
//! SG, D-C and W-C on the Amazon-Movie-like workload at 16/32/64/128
//! workers, with D-C/W-C tested at both "top-100" and "top-1000" key
//! capacities (the paper's D-C100 / D-C1000 / W-C100 / W-C1000 series).
//!
//! Paper shape to reproduce: FG/PKG p99 latency blows up with skew;
//! D-C100/W-C100 improve latency but their memory approaches SG as
//! workers scale; SG memory overhead grows ~linearly with workers.

#[path = "support/mod.rs"]
mod support;

use fish::coordinator::SchemeKind;
use fish::report::{ns, ratio, Table};
use support::*;

fn main() {
    println!("=== Paper Figs. 2 & 3: motivating study (AM-like workload) ===\n");

    let mut lat = Table::new(
        "Fig. 2 — latency (avg / p99) by scheme and worker count",
        &["workers", "scheme", "avg", "p99"],
    );
    let mut mem = Table::new(
        "Fig. 3 — memory overhead normalised to FG",
        &["workers", "scheme", "entries", "vs FG"],
    );

    for &w in &WORKER_SCALES {
        // (label, scheme, key capacity)
        let series: [(&str, SchemeKind, usize); 7] = [
            ("fg", SchemeKind::Field, 1000),
            ("pkg", SchemeKind::Pkg, 1000),
            ("sg", SchemeKind::Shuffle, 1000),
            ("dc100", SchemeKind::DChoices, 100),
            ("dc1000", SchemeKind::DChoices, 1000),
            ("wc100", SchemeKind::WChoices, 100),
            ("wc1000", SchemeKind::WChoices, 1000),
        ];
        for (label, kind, cap) in series {
            let mut cfg = base_config("am", w, 1.5);
            cfg.key_capacity = cap;
            let r = run_scheme(cfg, kind);
            lat.row(&[
                w.to_string(),
                label.into(),
                ns(r.latency.mean() as u64),
                ns(r.latency.quantile(0.99)),
            ]);
            mem.row(&[
                w.to_string(),
                label.into(),
                r.entries.to_string(),
                ratio(r.memory_normalized),
            ]);
        }
    }
    finish(&lat, "fig02_latency");
    finish(&mem, "fig03_memory");
}
