//! Paper Fig. 17 — effectiveness of consistent hashing under worker
//! churn.
//!
//! A worker is added (a) or removed (b) at the halfway point; FISH with
//! the consistent-hash ring vs FISH with modulo hashing, across skew.
//!
//! Paper shape: without CH, low-skew streams pay ≈2x the memory overhead
//! (every key-to-worker mapping shifts); high-skew streams pay less
//! because hot keys were already replicated on many workers.

#[path = "support/mod.rs"]
mod support;

use fish::coordinator::fish::CandidateMode;
use fish::coordinator::{Fish, Grouper};
use fish::engine::{sim::Simulator, ChurnEvent, Topology};
use fish::report::{ratio, Table};
use support::*;

fn run_mode(
    cfg: &fish::config::Config,
    mode: CandidateMode,
    churn: Vec<(usize, ChurnEvent)>,
) -> fish::engine::SimResult {
    let topology =
        Topology::from_config(cfg).with_churn(churn, cfg.service_ns as f64);
    let sources: Vec<Box<dyn Grouper>> = (0..cfg.sources)
        .map(|s| Box::new(Fish::from_config(cfg, s).with_mode(mode)) as Box<dyn Grouper>)
        .collect();
    let mut sim = Simulator::new(topology, sources, cfg.interarrival_ns);
    let mut gen = fish::workload::by_name(&cfg.workload, cfg.tuples, cfg.zipf_z, cfg.seed);
    sim.run(gen.as_mut())
}

fn main() {
    println!("=== Paper Fig. 17: consistent hashing under churn ===\n");
    let mut t = Table::new(
        "Fig. 17 — memory entries with/without CH (churn at 50%)",
        &["scenario", "z", "w/ CH", "w/o CH", "w/o / w/", "migrated w/CH", "migrated w/o"],
    );
    for (scenario, mk) in [
        ("add", Box::new(|cfg: &fish::config::Config| {
            vec![(cfg.tuples / 2, ChurnEvent::Add(cfg.workers))]
        }) as Box<dyn Fn(&fish::config::Config) -> Vec<(usize, ChurnEvent)>>),
        ("remove", Box::new(|cfg: &fish::config::Config| {
            vec![(cfg.tuples / 2, ChurnEvent::Remove(cfg.workers / 2))]
        })),
    ] {
        for &z in &z_values() {
            let mut cfg = base_config("zf", 32, z);
            cfg.tuples = (sim_tuples() / 2).max(100_000);
            let churn = mk(&cfg);
            let ch = run_mode(&cfg, CandidateMode::ConsistentHash, churn.clone());
            let nch = run_mode(&cfg, CandidateMode::ModuloHash, churn);
            t.row(&[
                scenario.into(),
                format!("{z:.1}"),
                ch.entries.to_string(),
                nch.entries.to_string(),
                ratio(nch.entries as f64 / ch.entries.max(1) as f64),
                ch.churn_migrations.to_string(),
                nch.churn_migrations.to_string(),
            ]);
        }
    }
    finish(&t, "fig17_ch");
}
