//! Paper Figs. 10 + 11 — execution time (vs SG) and memory overhead
//! (vs FG) on the synthetic time-evolving Zipf dataset, sweeping the
//! skew exponent z and the worker count.
//!
//! Paper shape: the scheme gap widens with workers; PKG worst; D-C/W-C
//! degrade with skew (up to 13.57x / 12.05x vs FISH); FISH stays within
//! 1.32x of SG while its memory stays within 1.11–2.61x of FG (SG's
//! memory reaches 15–88x).

#[path = "support/mod.rs"]
mod support;

use fish::coordinator::SchemeKind;
use fish::report::{ratio, Table};
use support::*;

fn main() {
    println!("=== Paper Figs. 10 & 11: ZF skew sweep ===\n");
    let mut exec = Table::new(
        "Fig. 10 — execution time normalised to SG",
        &["z", "workers", "pkg", "dc", "wc", "fish"],
    );
    let mut mem = Table::new(
        "Fig. 11 — memory overhead normalised to FG",
        &["z", "workers", "sg", "pkg", "dc", "wc", "fish"],
    );

    for &z in &z_values() {
        for &w in &WORKER_SCALES {
            let cfg = base_config("zf", w, z);
            let mut exec_cells = vec![format!("{z:.1}"), w.to_string()];
            let mut mem_cells = vec![format!("{z:.1}"), w.to_string()];
            let sg = run_scheme(cfg.clone(), SchemeKind::Shuffle);
            mem_cells.push(ratio(sg.memory_normalized));
            for kind in [
                SchemeKind::Pkg,
                SchemeKind::DChoices,
                SchemeKind::WChoices,
                SchemeKind::Fish,
            ] {
                let r = run_scheme(cfg.clone(), kind);
                exec_cells.push(ratio(r.makespan as f64 / sg.makespan.max(1) as f64));
                mem_cells.push(ratio(r.memory_normalized));
            }
            exec.row(&exec_cells);
            mem.row(&mem_cells);
        }
    }
    finish(&exec, "fig10_zipf_exec");
    finish(&mem, "fig11_zipf_memory");
}
