//! Paper Fig. 15 — effectiveness of hot-key classification (CHK).
//!
//! FISH's frequency-proportional ladder (CHK) vs the same pipeline with
//! W-C-style classification (every hot key on all workers) and D-C-style
//! (same fixed d for every hot key).
//!
//! Paper shape: w/W-C inflates memory (FISH saves 25–45% at 64/128
//! workers); w/D-C can use slightly less memory but pays execution time.

#[path = "support/mod.rs"]
mod support;

use fish::coordinator::fish::ChkMode;
use fish::coordinator::{Fish, Grouper, SchemeKind};
use fish::engine::{sim::Simulator, Topology};
use fish::report::{ratio, Table};
use support::*;

fn run_mode(cfg: &fish::config::Config, mode: Option<ChkMode>) -> fish::engine::SimResult {
    let topology = Topology::from_config(cfg);
    let sources: Vec<Box<dyn Grouper>> = (0..cfg.sources)
        .map(|s| -> Box<dyn Grouper> {
            match mode {
                None => fish::coordinator::make_kind(SchemeKind::Fish, cfg, s),
                Some(m) => Box::new(Fish::from_config(cfg, s).with_chk_mode(m)),
            }
        })
        .collect();
    let mut sim = Simulator::new(topology, sources, cfg.interarrival_ns);
    let mut gen = fish::workload::by_name(&cfg.workload, cfg.tuples, cfg.zipf_z, cfg.seed);
    sim.run(gen.as_mut())
}

fn main() {
    println!("=== Paper Fig. 15: CHK ablation ===\n");
    let mut t = Table::new(
        "Fig. 15 — memory (vs CHK) and execution (vs SG) per strategy",
        &["workers", "z", "strategy", "mem vs CHK", "exec vs SG"],
    );
    for &w in &[64usize, 128] {
        for &z in &z_values() {
            let cfg = base_config("zf", w, z);
            let sg = run_scheme(cfg.clone(), SchemeKind::Shuffle);
            let chk = run_mode(&cfg, None);
            let wc = run_mode(&cfg, Some(ChkMode::AllWorkers));
            let dc = run_mode(&cfg, Some(ChkMode::FixedD(4)));
            for (label, r) in [("chk", &chk), ("w/W-C", &wc), ("w/D-C", &dc)] {
                t.row(&[
                    w.to_string(),
                    format!("{z:.1}"),
                    label.into(),
                    ratio(r.entries as f64 / chk.entries.max(1) as f64),
                    ratio(r.makespan as f64 / sg.makespan.max(1) as f64),
                ]);
            }
        }
    }
    finish(&t, "fig15_chk");
}
