//! Shared support for the figure-reproduction benches.
//!
//! Every bench is a `harness = false` binary: it runs the experiment grid
//! for one paper figure, prints the same rows/series the paper reports,
//! and saves a CSV under `bench_out/` (override via `FISH_BENCH_OUT`).
//!
//! All run-shaping knobs come through one [`BenchOpts`] struct (instead
//! of ad-hoc env reads scattered per bench), and every CSV/JSON a bench
//! saves carries the run metadata — scale, seed, git SHA — so saved
//! series are reproducible and comparable across machines:
//!
//! * `FISH_BENCH_SCALE` — tuple-count multiplier, fractional allowed
//!   (`0.05` = CI smoke scale; the paper's full 50M-tuple runs ≈ 100).
//! * `FISH_BENCH_SEED` — PRNG seed for generated key streams.
//! * `FISH_BENCH_FULL_Z` — run all eleven Zipf exponents, not 3.
//! * `FISH_BENCH_OUT` — output directory (default `bench_out/`).

// Each bench includes this module by path and uses its own subset.
#![allow(dead_code)]

use fish::config::Config;
use fish::coordinator::SchemeKind;
use fish::engine::sim::SimResult;
use fish::engine::Pipeline;
use std::path::PathBuf;
use std::sync::OnceLock;

/// Worker scales used across the paper's figures.
pub const WORKER_SCALES: [usize; 4] = [16, 32, 64, 128];

/// Baseline tuple count the simulator benches scale from.
pub const SIM_TUPLES_BASE: usize = 200_000;

/// One resolved set of bench-run options (env-derived, read once).
#[derive(Debug, Clone)]
pub struct BenchOpts {
    /// Tuple-count scale factor (fractional allowed; 1.0 = laptop-sized).
    pub scale: f64,
    /// PRNG seed for generated key streams.
    pub seed: u64,
    /// Sweep all eleven Zipf exponents instead of the 3-point sample.
    pub full_z: bool,
    /// Directory CSV/JSON outputs land in.
    pub out_dir: PathBuf,
    /// Git SHA of the tree under test (`GITHUB_SHA`, else `git
    /// rev-parse`, else `unknown`) — stamped into every saved file.
    pub git_sha: String,
}

impl BenchOpts {
    /// Resolve options from the environment.
    pub fn from_env() -> Self {
        let scale = std::env::var("FISH_BENCH_SCALE")
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .filter(|s| *s > 0.0)
            .unwrap_or(1.0);
        let seed = std::env::var("FISH_BENCH_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(42);
        // resolved once per process: the legacy shims re-call from_env()
        // per config point, and forking `git` each time would dominate
        // small sweeps
        static GIT_SHA: OnceLock<String> = OnceLock::new();
        let git_sha = GIT_SHA
            .get_or_init(|| {
                std::env::var("GITHUB_SHA")
                    .ok()
                    .filter(|s| !s.is_empty())
                    .or_else(|| {
                        std::process::Command::new("git")
                            .args(["rev-parse", "HEAD"])
                            .output()
                            .ok()
                            .filter(|o| o.status.success())
                            .map(|o| String::from_utf8_lossy(&o.stdout).trim().to_string())
                    })
                    .filter(|s| !s.is_empty())
                    .unwrap_or_else(|| "unknown".to_string())
            })
            .clone();
        BenchOpts {
            scale,
            seed,
            full_z: std::env::var("FISH_BENCH_FULL_Z").is_ok(),
            out_dir: fish::report::bench_out(),
            git_sha,
        }
    }

    /// Scale a baseline tuple count (floored so smoke runs stay sane).
    pub fn tuples(&self, base: usize) -> usize {
        ((base as f64 * self.scale) as usize).max(1_000)
    }

    /// Zipf exponents to sweep (paper: 1.0..=2.0).
    pub fn z_values(&self) -> Vec<f64> {
        if self.full_z {
            (0..=10).map(|i| 1.0 + i as f64 * 0.1).collect()
        } else {
            vec![1.0, 1.5, 2.0]
        }
    }

    /// Run metadata stamped into every saved CSV/JSON.
    pub fn meta(&self) -> Vec<(String, String)> {
        vec![
            ("scale".into(), format!("{}", self.scale)),
            ("seed".into(), self.seed.to_string()),
            ("git_sha".into(), self.git_sha.clone()),
        ]
    }

    /// The same metadata as a JSON object fragment.
    pub fn meta_json(&self) -> String {
        format!(
            "{{\"scale\": {}, \"seed\": {}, \"git_sha\": \"{}\"}}",
            self.scale, self.seed, self.git_sha
        )
    }
}

/// Zipf exponents from the process environment (legacy shim — new code
/// should hold a [`BenchOpts`]).
pub fn z_values() -> Vec<f64> {
    BenchOpts::from_env().z_values()
}

/// Integer tuple-count scale factor (legacy shim; fractional scales
/// clamp to 1 — only [`BenchOpts::tuples`] honours them).
pub fn scale() -> usize {
    (BenchOpts::from_env().scale.round() as usize).max(1)
}

/// Baseline tuple count for simulator benches.
pub fn sim_tuples() -> usize {
    BenchOpts::from_env().tuples(SIM_TUPLES_BASE)
}

/// A base config tuned so arrivals keep `workers` busy without
/// unbounded queue growth (arrival rate ≈ aggregate service rate).
pub fn base_config(workload: &str, workers: usize, z: f64) -> Config {
    let opts = BenchOpts::from_env();
    let mut cfg = Config::default();
    cfg.workload = workload.into();
    cfg.tuples = opts.tuples(SIM_TUPLES_BASE);
    cfg.zipf_z = z;
    cfg.workers = workers;
    cfg.sources = 4;
    cfg.seed = opts.seed;
    cfg.service_ns = 1_000;
    cfg.interarrival_ns = (cfg.service_ns / workers as u64).max(1);
    // K_max proportional to the key space, as in the paper (1000 counters
    // over 0.1–0.39M keys ≈ 0.3–1%); our scaled streams have ~2–100k keys.
    cfg.key_capacity = 200;
    cfg
}

/// Run one scheme on a config through the pipeline builder.
pub fn run_scheme(mut cfg: Config, kind: SchemeKind) -> SimResult {
    cfg.scheme = kind;
    Pipeline::builder().config(cfg).build_sim().run()
}

/// Run SG alongside `kind` and return (result, exec-time ratio vs SG) —
/// the normalisation the paper uses in Figs. 9, 10.
pub fn run_vs_sg(cfg: &Config, kind: SchemeKind) -> (SimResult, f64) {
    let sg = run_scheme(cfg.clone(), SchemeKind::Shuffle);
    let r = run_scheme(cfg.clone(), kind);
    let ratio = r.makespan as f64 / sg.makespan.max(1) as f64;
    (r, ratio)
}

/// Save + print helper: prints the table and writes
/// `bench_out/<name>.csv` with the run metadata as leading `# key=value`
/// comment lines.
pub fn finish(table: &fish::report::Table, name: &str) {
    finish_with(&BenchOpts::from_env(), table, name);
}

/// [`finish`] against an already-resolved [`BenchOpts`].
pub fn finish_with(opts: &BenchOpts, table: &fish::report::Table, name: &str) {
    table.print();
    let path = opts.out_dir.join(format!("{name}.csv"));
    match table.save_csv_with_meta(&path, &opts.meta()) {
        Ok(()) => println!("[saved {}]\n", path.display()),
        Err(e) => eprintln!("[csv save failed: {e}]\n"),
    }
}

/// Write a machine-readable JSON document under the bench output dir.
pub fn save_json(opts: &BenchOpts, name: &str, json: &str) -> std::io::Result<PathBuf> {
    std::fs::create_dir_all(&opts.out_dir)?;
    let path = opts.out_dir.join(name);
    std::fs::write(&path, json)?;
    Ok(path)
}
