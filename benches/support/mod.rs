//! Shared support for the figure-reproduction benches.
//!
//! Every bench is a `harness = false` binary: it runs the experiment grid
//! for one paper figure, prints the same rows/series the paper reports,
//! and saves a CSV under `bench_out/` (override via `FISH_BENCH_OUT`).
//!
//! Scale: defaults are sized to finish the whole `cargo bench` suite in
//! minutes on a laptop. `FISH_BENCH_SCALE=4` multiplies tuple counts
//! (the paper's full 50M-tuple runs ≈ scale 100).

use fish::config::Config;
use fish::coordinator::SchemeKind;
use fish::engine::sim::SimResult;
use fish::engine::Pipeline;

/// Worker scales used across the paper's figures.
pub const WORKER_SCALES: [usize; 4] = [16, 32, 64, 128];

/// Zipf exponents (paper: 1.0..=2.0; we sample the ends and middle by
/// default — `FISH_BENCH_FULL_Z=1` runs all eleven).
pub fn z_values() -> Vec<f64> {
    if std::env::var("FISH_BENCH_FULL_Z").is_ok() {
        (0..=10).map(|i| 1.0 + i as f64 * 0.1).collect()
    } else {
        vec![1.0, 1.5, 2.0]
    }
}

/// Tuple-count scale factor.
pub fn scale() -> usize {
    std::env::var("FISH_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1)
}

/// Baseline tuple count for simulator benches.
pub fn sim_tuples() -> usize {
    200_000 * scale()
}

/// A base config tuned so arrivals keep `workers` busy without
/// unbounded queue growth (arrival rate ≈ aggregate service rate).
pub fn base_config(workload: &str, workers: usize, z: f64) -> Config {
    let mut cfg = Config::default();
    cfg.workload = workload.into();
    cfg.tuples = sim_tuples();
    cfg.zipf_z = z;
    cfg.workers = workers;
    cfg.sources = 4;
    cfg.service_ns = 1_000;
    cfg.interarrival_ns = (cfg.service_ns / workers as u64).max(1);
    // K_max proportional to the key space, as in the paper (1000 counters
    // over 0.1–0.39M keys ≈ 0.3–1%); our scaled streams have ~2–100k keys.
    cfg.key_capacity = 200;
    cfg
}

/// Run one scheme on a config through the pipeline builder.
pub fn run_scheme(mut cfg: Config, kind: SchemeKind) -> SimResult {
    cfg.scheme = kind;
    Pipeline::builder().config(cfg).build_sim().run()
}

/// Run SG alongside `kind` and return (result, exec-time ratio vs SG) —
/// the normalisation the paper uses in Figs. 9, 10.
pub fn run_vs_sg(cfg: &Config, kind: SchemeKind) -> (SimResult, f64) {
    let sg = run_scheme(cfg.clone(), SchemeKind::Shuffle);
    let r = run_scheme(cfg.clone(), kind);
    let ratio = r.makespan as f64 / sg.makespan.max(1) as f64;
    (r, ratio)
}

/// Save + print helper: prints the table and writes `bench_out/<name>.csv`.
pub fn finish(table: &fish::report::Table, name: &str) {
    table.print();
    let path = fish::report::bench_out().join(format!("{name}.csv"));
    match table.save_csv(&path) {
        Ok(()) => println!("[saved {}]\n", path.display()),
        Err(e) => eprintln!("[csv save failed: {e}]\n"),
    }
}
