//! Paper Fig. 16 — effectiveness of heuristic worker assignment (HWA).
//!
//! Half the workers have twice the processing capability (the paper's
//! setup). FISH with HWA (infer backlog × capacity, Alg. 3) vs FISH with
//! the prior work's count-based assignment (evenly split tuple counts).
//!
//! Paper shape: up to 2.61x execution-time improvement from HWA on the
//! heterogeneous cluster.

#[path = "support/mod.rs"]
mod support;

use fish::coordinator::{Fish, Grouper, SchemeKind};
use fish::engine::{sim::Simulator, Topology};
use fish::report::{ratio, Table};
use support::*;

fn run_fish(cfg: &fish::config::Config, count_based: bool) -> fish::engine::SimResult {
    let topology = Topology::from_config(cfg);
    let sources: Vec<Box<dyn Grouper>> = (0..cfg.sources)
        .map(|s| -> Box<dyn Grouper> {
            let f = Fish::from_config(cfg, s);
            if count_based {
                Box::new(f.with_count_based_assignment())
            } else {
                Box::new(f)
            }
        })
        .collect();
    let mut sim = Simulator::new(topology, sources, cfg.interarrival_ns);
    let mut gen = fish::workload::by_name(&cfg.workload, cfg.tuples, cfg.zipf_z, cfg.seed);
    sim.run(gen.as_mut())
}

fn main() {
    println!("=== Paper Fig. 16: HWA ablation (heterogeneous cluster) ===\n");
    let mut t = Table::new(
        "Fig. 16 — execution time, half the workers at 2x capacity",
        &["workers", "z", "w/ hwa vs SG", "w/o hwa vs SG", "hwa gain"],
    );
    for &w in &WORKER_SCALES {
        for &z in &z_values() {
            let mut cfg = base_config("zf", w, z);
            cfg.capacities = vec![1.0, 2.0]; // half the cluster is 2x
            // arrival tuned to aggregate capacity (1.5x homogeneous)
            cfg.interarrival_ns =
                ((cfg.service_ns as f64 / (1.5 * w as f64)) as u64).max(1);
            let sg = run_scheme(cfg.clone(), SchemeKind::Shuffle);
            let with_hwa = run_fish(&cfg, false);
            let without = run_fish(&cfg, true);
            t.row(&[
                w.to_string(),
                format!("{z:.1}"),
                ratio(with_hwa.makespan as f64 / sg.makespan.max(1) as f64),
                ratio(without.makespan as f64 / sg.makespan.max(1) as f64),
                ratio(without.makespan as f64 / with_hwa.makespan.max(1) as f64),
            ]);
        }
    }
    finish(&t, "fig16_hwa");
}
