//! Paper Fig. 12 — choosing the decay factor α.
//!
//! Execution time and memory overhead as a function of skew for
//! α ∈ {0, 0.2, 0.5, 0.8, 1.0} at several worker counts.
//!
//! Paper shape: α = 1 (no decay — lifetime counting) blows up execution
//! time as skew rises (up to 12.14x vs α = 0.2); α = 0 (forget
//! everything) costs memory on low-skew data (≈2.65x vs α = 0.2);
//! α = 0.2 is the sweet spot.

#[path = "support/mod.rs"]
mod support;

use fish::coordinator::SchemeKind;
use fish::report::{ratio, Table};
use support::*;

fn main() {
    println!("=== Paper Fig. 12: decay factor sweep ===\n");
    let alphas = [0.0, 0.2, 0.5, 0.8, 1.0];
    let mut t = Table::new(
        "Fig. 12 — execution (vs SG) and memory (vs FG) per alpha",
        &["workers", "z", "alpha", "exec vs SG", "mem vs FG"],
    );
    for &w in &[16usize, 128] {
        for &z in &z_values() {
            let sg = run_scheme(base_config("zf", w, z), SchemeKind::Shuffle);
            for &alpha in &alphas {
                let mut cfg = base_config("zf", w, z);
                cfg.alpha = alpha;
                let r = run_scheme(cfg, SchemeKind::Fish);
                t.row(&[
                    w.to_string(),
                    format!("{z:.1}"),
                    format!("{alpha:.1}"),
                    ratio(r.makespan as f64 / sg.makespan.max(1) as f64),
                    ratio(r.memory_normalized),
                ]);
            }
        }
    }
    finish(&t, "fig12_alpha");
}
